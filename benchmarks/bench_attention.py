"""Block-sparse attention benchmark: full-grid flash vs the BCSR stream walk.

The contrast this PR ships: long-context sliding-window attention pays the
full S^2/(bq*bk) KV tile grid in the dense kernel (whole-tile -1e30 masking
for everything outside the band), while the sparse walk steps only the
visible-tile stream lowered from the ``BlockMask`` -- roughly
2*S*W/(bq*bk) tiles for a width-W band.  Each point records the structural
walked-tile counts (raw and bucket-padded -- the count the compiled grid
actually steps) next to the measured wall times and an exact-parity flag
against the dense-masked kernel, so the JSON artifact is both the perf
record and the correctness record.

CPU wall-clock caveat (benchmarks/common.py): interpret-mode times are
emulation times, meaningful relatively (tile-count scaling), not absolutely.

  python benchmarks/bench_attention.py           # S=4096 -> BENCH_attention.json
  python benchmarks/bench_attention.py --smoke   # tiny shapes (CI guard)
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit_bench, row, time_fn
from repro.core.masks import BlockMask
from repro.kernels import tuning
from repro.kernels.flash_attention import ops as fops


def run(*, smoke: bool = False) -> dict:
    if smoke:
        B, H, S, D, bq, bk = 1, 1, 128, 32, 32, 32
        iters, warmup = 1, 1
    else:
        B, H, S, D, bq, bk = 1, 1, 4096, 64, 128, 128
        iters, warmup = 3, 1
    interpret = not tuning.on_tpu()
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, S, D), jnp.float32)

    dense_tiles = (S // bq) * (S // bk)
    points = []
    for frac, window in [("1/8", S // 8), ("1/4", S // 4), ("1/2", S // 2)]:
        mask = BlockMask.sliding_window(S, S, window, bq=bq, bk=bk)
        walked = mask.lower(bucket=False).capacity
        bucketed = mask.lower(bucket=True).capacity

        def dense_fn():
            # the pre-existing kernel: full KV grid, whole-tile masking
            return fops.attention(q, k, v, causal=True, window=window,
                                  bq=bq, bk=bk, interpret=interpret)

        def sparse_fn():
            return fops.attention(q, k, v, mask=mask, mask_impl="sparse",
                                  interpret=interpret)

        t_dense = time_fn(dense_fn, warmup=warmup, iters=iters)
        t_sparse = time_fn(sparse_fn, warmup=warmup, iters=iters)
        parity = bool(np.array_equal(np.asarray(sparse_fn()),
                                     np.asarray(dense_fn())))
        points.append({
            "window": window, "window_frac": frac,
            "walked_tiles": walked,
            "walked_tiles_bucketed": bucketed,
            "dense_tiles": dense_tiles,
            "tile_reduction": dense_tiles / bucketed,
            "t_dense_us": t_dense * 1e6,
            "t_sparse_us": t_sparse * 1e6,
            "speedup": t_dense / t_sparse,
            "parity_bit_identical": parity,
        })

    return {"shape": {"B": B, "H": H, "S": S, "D": D, "bq": bq, "bk": bk},
            "dense_tiles": dense_tiles, "points": points,
            "interpret": interpret, "smoke": smoke}


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    results = run(smoke=smoke)
    rows = []
    for p in results["points"]:
        detail = (f"W={p['window']};walked={p['walked_tiles']}"
                  f"(bucket {p['walked_tiles_bucketed']})"
                  f"/dense={p['dense_tiles']};speedup={p['speedup']:.2f}x"
                  f";parity={p['parity_bit_identical']}")
        rows.append(row("attention/sparse_walk", p["t_sparse_us"], detail))
        rows.append(row("attention/dense_grid", p["t_dense_us"],
                        f"W={p['window']}"))
    results["rows"] = rows
    path = emit_bench("attention", results)
    print("\n".join(rows))
    print(f"# wrote {path}")
