"""Measured autotune sweep: (bn, nt, bucket) on real shapes, winners
registered into the tile table.

ROADMAP "measured, not heuristic, autotune rows": the static table in
``repro.kernels.tuning`` was sized from the roofline model; this harness
*measures* the candidate grid on the attached backend and calls
``tuning.register`` with the winners, so a process that runs the sweep first
serves every later kernel call from measured rows.  Artifacts go to
``BENCH_sweep_tiles.json`` (every point, not just winners -- the losing
points are the record of *why* the winner won).

Axes swept per op:
  * ``spmm``          -- bn x nt (dense N-tile x output-residency width) on a
    block-uniform BCSR x dense of the benchmark shapes.  The structural
    stream-walk count rides along with each timing: on interpret-mode CPU
    the wall clock is emulation-dominated, so the winner is chosen by
    (walks, time) lexicographically on TPU and time-only on CPU.
  * ``moe_dispatch``  -- min_bucket floors for the two-phase serving loop:
    the bucket trades zero-block stream work against phase-2 recompiles, so
    the sweep scores ``route+execute`` wall time of a decode-shaped step
    per floor.
  * ``flash``         -- (bq, bk) on a causal prefill shape, dense grid and
    the block-sparse sliding-window walk.  The sparse rows carry their
    walked-tile counts (bk is also the mask's pattern resolution: narrower
    KV tiles prune the window edge tighter but walk a longer stream); the
    winner registers the base ``flash_sparse`` row plus a per-pattern
    ``{"patterns": {"window": ...}}`` override (``tuning
    .flash_sparse_tiles``).

Run modes:
  python benchmarks/sweep_tiles.py                 # full sweep + register
  python benchmarks/sweep_tiles.py --smoke         # one tiny point per op
                                                   # (the CI bit-rot guard)
"""
from __future__ import annotations

import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit_bench, row, time_fn
from repro.configs import get_smoke
from repro.core.formats import bcsr_from_dense
from repro.kernels import tuning
from repro.kernels.spmm import ops as spmm_ops
from repro.kernels.spmm.kernel import stream_walks
from repro.models import moe as moe_mod


def _block_uniform(rng, shape, density, block=(8, 8)):
    gm, gn = shape[0] // block[0], shape[1] // block[1]
    mask = np.kron(rng.random((gm, gn)) < density, np.ones(block, bool))
    return np.where(mask, rng.standard_normal(shape), 0).astype(np.float32)


def sweep_spmm(*, smoke: bool = False, register: bool = True) -> dict:
    """Sweep (bn, nt) for the BCSR SpMM kernel; returns the point table and
    (optionally) registers the winner for the current platform."""
    rng = np.random.default_rng(0)
    if smoke:
        M_, K_, N_ = 64, 64, 256
        bns, nts = (128,), (1, 2)
    else:
        M_, K_, N_ = 1024, 1024, 1024
        bns, nts = (128, 256, 512), (1, 2, 4, 8)
    a = bcsr_from_dense(_block_uniform(rng, (M_, K_), 0.05), (8, 8))
    b = jnp.asarray(rng.standard_normal((K_, N_)), jnp.float32)
    interpret = not tuning.on_tpu()

    points = []
    ref = np.asarray(spmm_ops.spmm(a, b, bn=bns[0], nt=1,
                                   interpret=interpret))
    for bn in bns:
        if bn > N_:
            continue
        for nt in nts:
            if nt * bn > N_:
                continue
            t = time_fn(lambda bn=bn, nt=nt: spmm_ops.spmm(
                a, b, bn=bn, nt=nt, interpret=interpret))
            out = np.asarray(spmm_ops.spmm(a, b, bn=bn, nt=nt,
                                           interpret=interpret))
            points.append({"bn": bn, "nt": nt, "t_us": t * 1e6,
                           "stream_walks": stream_walks(N_, bn, nt),
                           "bit_identical": bool((out == ref).all())})
    assert all(p["bit_identical"] for p in points), "sweep found divergence"
    # TPU: fewer stream walks first (the HBM term), wall time second;
    # interpret-mode CPU: wall time only (walks measure nothing there).
    key = ((lambda p: (p["stream_walks"], p["t_us"])) if tuning.on_tpu()
           else (lambda p: p["t_us"]))
    best = min(points, key=key)
    if register:
        tuning.register("spmm", jnp.float32,
                        {"bn": best["bn"], "nt": best["nt"]})
    return {"shape": {"M": M_, "K": K_, "N": N_, "nnzb": int(a.nnzb)},
            "points": points, "winner": best, "registered": bool(register)}


def sweep_moe_bucket(*, smoke: bool = False, register: bool = True) -> dict:
    """Sweep the two-phase min_bucket floor on a decode-shaped MoE layer:
    score = route + execute wall time at (B, S=1) after warmup, so both the
    zero-block stream tax (large floors) and the recompile tax (small
    floors, if the routed count wobbles across buckets) are in the
    measurement."""
    rng = np.random.default_rng(0)
    E_, D_ = (4, 64) if smoke else (16, 128)
    floors = (8,) if smoke else (8, 16, 32, 64)
    cfg = dataclasses.replace(
        get_smoke("llama4-scout-17b-a16e"), d_model=D_, d_ff=2 * D_,
        n_experts=E_, capacity_factor=1.25, moe_shared_expert=False)
    params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x1 = jnp.asarray(rng.standard_normal((2, 1, D_)), jnp.float32)
    # snapshot the RAW table row (not the shape-clamped lookup): the sweep
    # varies only min_bucket, and the restore below must not bake this
    # benchmark's small-d_model bn/nt clamps into the global row
    raw = tuning._row("moe_dispatch", jnp.float32)

    points = []
    for floor in floors:
        tuning.register("moe_dispatch", jnp.float32,
                        {**raw, "min_bucket": floor})

        def step():
            plan, _ = moe_mod.route_moe(params, x1, cfg, dispatch="bcsr",
                                        pos=7)
            return moe_mod.execute_moe_jit(params, x1, plan, cfg)[0]

        t = time_fn(step)
        _, info = moe_mod.route_moe(params, x1, cfg, dispatch="bcsr", pos=7)
        points.append({"min_bucket": floor, "t_us": t * 1e6,
                       "nnzb_stream": info["nnzb_stream"],
                       "nnzb_covered": info["nnzb_covered"]})
    best = min(points, key=lambda p: p["t_us"])
    # leave the table on the winning row (or restore the raw row untouched
    # when the caller asked for a measurement-only run)
    tuning.register("moe_dispatch", jnp.float32,
                    {**raw, "min_bucket": best["min_bucket"]} if register
                    else raw)
    return {"shape": {"experts": E_, "d_model": D_, "tokens": [2, 1]},
            "points": points, "winner": best, "registered": bool(register)}


def sweep_flash(*, smoke: bool = False, register: bool = True) -> dict:
    """Sweep flash-attention (bq, bk) on a causal prefill shape: the dense
    full-grid kernel and the block-sparse sliding-window walk, every point
    parity-checked against the bq/bk-independent jnp oracle.  Winners go to
    the ``"flash"`` row and the ``"flash_sparse"`` row (base + a
    ``"patterns": {"window": ...}`` override -- the sparse walk may prefer a
    different KV tile than the dense grid, since bk doubles as the mask's
    pattern resolution)."""
    from repro.core.masks import BlockMask
    from repro.kernels.flash_attention import ops as fops
    from repro.kernels.flash_attention.ref import attention_ref

    rng = jax.random.split(jax.random.PRNGKey(0), 3)
    if smoke:
        B, H, S, D = 1, 1, 64, 16
        tiles = ((16, 16), (16, 32))
    else:
        B, H, S, D = 1, 2, 1024, 64
        tiles = ((64, 64), (64, 128), (128, 128), (128, 256))
    window = S // 4
    q = jax.random.normal(rng[0], (B, H, S, D), jnp.float32)
    k = jax.random.normal(rng[1], (B, H, S, D), jnp.float32)
    v = jax.random.normal(rng[2], (B, H, S, D), jnp.float32)
    interpret = not tuning.on_tpu()
    ref = np.asarray(attention_ref(q, k, v, causal=True, window=window))

    dense_pts, sparse_pts = [], []
    for bq, bk in tiles:
        t_d = time_fn(lambda bq=bq, bk=bk: fops.attention(
            q, k, v, causal=True, window=window, bq=bq, bk=bk,
            interpret=interpret), warmup=1, iters=3)
        out = np.asarray(fops.attention(q, k, v, causal=True, window=window,
                                        bq=bq, bk=bk, interpret=interpret))
        ok = bool(np.allclose(out, ref, atol=2e-3, rtol=2e-3))
        dense_pts.append({"bq": bq, "bk": bk, "t_us": t_d * 1e6,
                          "tiles": (S // bq) * (S // bk), "parity": ok})

        mask = BlockMask.sliding_window(S, S, window, bq=bq, bk=bk)
        t_s = time_fn(lambda q=q, mask=mask: fops.attention(
            q, k, v, mask=mask, mask_impl="sparse", interpret=interpret),
            warmup=1, iters=3)
        outs = np.asarray(fops.attention(q, k, v, mask=mask,
                                         mask_impl="sparse",
                                         interpret=interpret))
        oks = bool(np.allclose(outs, ref, atol=2e-3, rtol=2e-3))
        sparse_pts.append({"bq": bq, "bk": bk, "t_us": t_s * 1e6,
                           "walked_tiles": mask.lower(bucket=True).capacity,
                           "parity": oks})
    assert all(p["parity"] for p in dense_pts + sparse_pts), \
        "flash sweep found divergence from the oracle"
    # TPU scores structure first (walked tiles ~ HBM traffic), CPU time only
    # (interpret emulation swamps the stream contrast at sweep shapes).
    d_key = ((lambda p: (p["tiles"], p["t_us"])) if tuning.on_tpu()
             else (lambda p: p["t_us"]))
    s_key = ((lambda p: (p["walked_tiles"], p["t_us"])) if tuning.on_tpu()
             else (lambda p: p["t_us"]))
    best_d = min(dense_pts, key=d_key)
    best_s = min(sparse_pts, key=s_key)
    if register:
        tuning.register("flash", jnp.float32,
                        {"bq": best_d["bq"], "bk": best_d["bk"]})
        base = tuning._row("flash_sparse", jnp.float32)
        tuning.register("flash_sparse", jnp.float32, {
            "bq": base["bq"], "bk": base["bk"],
            "patterns": {**base.get("patterns", {}),
                         "window": {"bq": best_s["bq"], "bk": best_s["bk"]}}})
    return {"shape": {"B": B, "H": H, "S": S, "D": D, "window": window},
            "dense_points": dense_pts, "sparse_points": sparse_pts,
            "points": dense_pts + sparse_pts,
            "winner": {**best_s, "dense_bq": best_d["bq"],
                       "dense_bk": best_d["bk"]},
            "registered": bool(register)}


def run(*, smoke: bool = False, register: bool = True) -> dict:
    return {"spmm": sweep_spmm(smoke=smoke, register=register),
            "moe_dispatch": sweep_moe_bucket(smoke=smoke, register=register),
            "flash": sweep_flash(smoke=smoke, register=register)}


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    results = run(smoke=smoke)
    rows = []
    for op, res in results.items():
        for p in res["points"]:
            detail = ";".join(f"{k}={v}" for k, v in p.items()
                              if k != "t_us")
            rows.append(row(f"sweep/{op}", p["t_us"], detail))
        rows.append(row(f"sweep/{op}/winner", res["winner"]["t_us"],
                        ";".join(f"{k}={v}" for k, v in res["winner"].items()
                                 if k != "t_us")))
    results["rows"] = rows
    results["smoke"] = smoke
    path = emit_bench("sweep_tiles", results)
    print("\n".join(rows))
    print(f"# wrote {path}")
