"""Measured autotune sweep: (bn, nt, bucket) on real shapes, winners
registered into the tile table.

ROADMAP "measured, not heuristic, autotune rows": the static table in
``repro.kernels.tuning`` was sized from the roofline model; this harness
*measures* the candidate grid on the attached backend and calls
``tuning.register`` with the winners, so a process that runs the sweep first
serves every later kernel call from measured rows.  Artifacts go to
``BENCH_sweep_tiles.json`` (every point, not just winners -- the losing
points are the record of *why* the winner won).

Axes swept per op:
  * ``spmm``          -- bn x nt (dense N-tile x output-residency width) on a
    block-uniform BCSR x dense of the benchmark shapes.  The structural
    stream-walk count rides along with each timing: on interpret-mode CPU
    the wall clock is emulation-dominated, so the winner is chosen by
    (walks, time) lexicographically on TPU and time-only on CPU.
  * ``moe_dispatch``  -- min_bucket floors for the two-phase serving loop:
    the bucket trades zero-block stream work against phase-2 recompiles, so
    the sweep scores ``route+execute`` wall time of a decode-shaped step
    per floor.

Run modes:
  python benchmarks/sweep_tiles.py                 # full sweep + register
  python benchmarks/sweep_tiles.py --smoke         # one tiny point per op
                                                   # (the CI bit-rot guard)
"""
from __future__ import annotations

import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit_bench, row, time_fn
from repro.configs import get_smoke
from repro.core.formats import bcsr_from_dense
from repro.kernels import tuning
from repro.kernels.spmm import ops as spmm_ops
from repro.kernels.spmm.kernel import stream_walks
from repro.models import moe as moe_mod


def _block_uniform(rng, shape, density, block=(8, 8)):
    gm, gn = shape[0] // block[0], shape[1] // block[1]
    mask = np.kron(rng.random((gm, gn)) < density, np.ones(block, bool))
    return np.where(mask, rng.standard_normal(shape), 0).astype(np.float32)


def sweep_spmm(*, smoke: bool = False, register: bool = True) -> dict:
    """Sweep (bn, nt) for the BCSR SpMM kernel; returns the point table and
    (optionally) registers the winner for the current platform."""
    rng = np.random.default_rng(0)
    if smoke:
        M_, K_, N_ = 64, 64, 256
        bns, nts = (128,), (1, 2)
    else:
        M_, K_, N_ = 1024, 1024, 1024
        bns, nts = (128, 256, 512), (1, 2, 4, 8)
    a = bcsr_from_dense(_block_uniform(rng, (M_, K_), 0.05), (8, 8))
    b = jnp.asarray(rng.standard_normal((K_, N_)), jnp.float32)
    interpret = not tuning.on_tpu()

    points = []
    ref = np.asarray(spmm_ops.spmm(a, b, bn=bns[0], nt=1,
                                   interpret=interpret))
    for bn in bns:
        if bn > N_:
            continue
        for nt in nts:
            if nt * bn > N_:
                continue
            t = time_fn(lambda bn=bn, nt=nt: spmm_ops.spmm(
                a, b, bn=bn, nt=nt, interpret=interpret))
            out = np.asarray(spmm_ops.spmm(a, b, bn=bn, nt=nt,
                                           interpret=interpret))
            points.append({"bn": bn, "nt": nt, "t_us": t * 1e6,
                           "stream_walks": stream_walks(N_, bn, nt),
                           "bit_identical": bool((out == ref).all())})
    assert all(p["bit_identical"] for p in points), "sweep found divergence"
    # TPU: fewer stream walks first (the HBM term), wall time second;
    # interpret-mode CPU: wall time only (walks measure nothing there).
    key = ((lambda p: (p["stream_walks"], p["t_us"])) if tuning.on_tpu()
           else (lambda p: p["t_us"]))
    best = min(points, key=key)
    if register:
        tuning.register("spmm", jnp.float32,
                        {"bn": best["bn"], "nt": best["nt"]})
    return {"shape": {"M": M_, "K": K_, "N": N_, "nnzb": int(a.nnzb)},
            "points": points, "winner": best, "registered": bool(register)}


def sweep_moe_bucket(*, smoke: bool = False, register: bool = True) -> dict:
    """Sweep the two-phase min_bucket floor on a decode-shaped MoE layer:
    score = route + execute wall time at (B, S=1) after warmup, so both the
    zero-block stream tax (large floors) and the recompile tax (small
    floors, if the routed count wobbles across buckets) are in the
    measurement."""
    rng = np.random.default_rng(0)
    E_, D_ = (4, 64) if smoke else (16, 128)
    floors = (8,) if smoke else (8, 16, 32, 64)
    cfg = dataclasses.replace(
        get_smoke("llama4-scout-17b-a16e"), d_model=D_, d_ff=2 * D_,
        n_experts=E_, capacity_factor=1.25, moe_shared_expert=False)
    params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x1 = jnp.asarray(rng.standard_normal((2, 1, D_)), jnp.float32)
    # snapshot the RAW table row (not the shape-clamped lookup): the sweep
    # varies only min_bucket, and the restore below must not bake this
    # benchmark's small-d_model bn/nt clamps into the global row
    raw = tuning._row("moe_dispatch", jnp.float32)

    points = []
    for floor in floors:
        tuning.register("moe_dispatch", jnp.float32,
                        {**raw, "min_bucket": floor})

        def step():
            plan, _ = moe_mod.route_moe(params, x1, cfg, dispatch="bcsr",
                                        pos=7)
            return moe_mod.execute_moe_jit(params, x1, plan, cfg)[0]

        t = time_fn(step)
        _, info = moe_mod.route_moe(params, x1, cfg, dispatch="bcsr", pos=7)
        points.append({"min_bucket": floor, "t_us": t * 1e6,
                       "nnzb_stream": info["nnzb_stream"],
                       "nnzb_covered": info["nnzb_covered"]})
    best = min(points, key=lambda p: p["t_us"])
    # leave the table on the winning row (or restore the raw row untouched
    # when the caller asked for a measurement-only run)
    tuning.register("moe_dispatch", jnp.float32,
                    {**raw, "min_bucket": best["min_bucket"]} if register
                    else raw)
    return {"shape": {"experts": E_, "d_model": D_, "tokens": [2, 1]},
            "points": points, "winner": best, "registered": bool(register)}


def run(*, smoke: bool = False, register: bool = True) -> dict:
    return {"spmm": sweep_spmm(smoke=smoke, register=register),
            "moe_dispatch": sweep_moe_bucket(smoke=smoke, register=register)}


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    results = run(smoke=smoke)
    rows = []
    for op, res in results.items():
        for p in res["points"]:
            detail = ";".join(f"{k}={v}" for k, v in p.items()
                              if k != "t_us")
            rows.append(row(f"sweep/{op}", p["t_us"], detail))
        rows.append(row(f"sweep/{op}/winner", res["winner"]["t_us"],
                        ";".join(f"{k}={v}" for k, v in res["winner"].items()
                                 if k != "t_us")))
    results["rows"] = rows
    results["smoke"] = smoke
    path = emit_bench("sweep_tiles", results)
    print("\n".join(rows))
    print(f"# wrote {path}")
