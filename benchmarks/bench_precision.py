"""Paper Tab. 1 / multi-precision ladder + the narrow-precision sparse sweep.

Occamy's FP64/32/16/8 SIMD ladder maps to the v5e MXU's f32/bf16/fp8 modes
(DESIGN.md S2.1): each narrowing step doubles peak FLOP/s; accumulation
always widens to f32 (the ExSdotp pattern). CPU wall times are emulation
artifacts for narrow types; the TPU-projected peaks are the Tab. 1 row.

Beyond the ladder, this bench now *measures* the per-block-scaled narrow
pipeline end to end (``BENCH_precision.json``):

* **spmm kernel sweep** -- the BCSR x dense kernel at f32 vs quantized
  fp8_e4m3 / fp8_e5m2 / int8 block values (per-block f32 scales, f32
  resident accumulator): wall time, effective GFLOP/s, max-abs error vs
  the f32 kernel, and the bit-identity check vs the
  dequantize-on-host-then-f32-kernel reference (the BlockQuant contract).
* **serving sweep** -- a tiny attn+moe arch through ``launch.serve
  .ServeLoop`` per narrow dtype with quantized expert weights AND a
  quantized KV cache: decode tok/s, greedy-token agreement with the f32
  loop, and the first-decode-step logit error (the tolerance-bounded
  serving contract; see tests/README.md "Narrow-precision contract").

Run modes:
  python benchmarks/bench_precision.py           # full sweep -> BENCH json
  python benchmarks/bench_precision.py --smoke   # CI-sized, same schema
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import PEAK_FLOPS, emit_bench, row, time_fn
from repro.core.precision import LADDER, PEAK_MULTIPLIER, policy

M = N = K = 1024

QUANT_NAMES = ("fp8_e4m3", "fp8_e5m2", "int8")


def _ladder_rows() -> list:
    rng = np.random.default_rng(0)
    rows = []
    a32 = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    b32 = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    flops = 2 * M * N * K
    for name in LADDER:
        pol = policy(name)

        @jax.jit
        def mm(a, b, pol=pol):
            return pol.dot(a, b)

        t = time_fn(mm, a32, b32)
        out = mm(a32, b32)
        assert out.dtype == jnp.float32, "accumulation must widen to f32"
        tpu_peak = PEAK_FLOPS["f32"] * PEAK_MULTIPLIER[name]
        rows.append(row(
            f"precision/{name}/widening_matmul", t * 1e6,
            f"cpu_gflops={flops / t / 1e9:.2f};"
            f"tpu_peak_tflops={tpu_peak / 1e12:.0f};"
            f"tpu_time_at_peak_us={flops / tpu_peak * 1e6:.2f};"
            f"accum=f32"))
    return rows


def _spmm_sweep(*, smoke: bool) -> dict:
    """Quantized-BCSR spmm vs the f32 kernel on one block-uniform case."""
    from repro.core.formats import bcsr_from_dense
    from repro.kernels.spmm import ops as spmm_ops

    m = k = 128 if smoke else 512
    n = 128 if smoke else 256
    density = 0.1
    rng = np.random.default_rng(0)
    gm, gk = m // 8, k // 8
    mask = np.kron(rng.random((gm, gk)) < density, np.ones((8, 8), bool))
    a_dense = np.where(mask, rng.standard_normal((m, k)), 0).astype(np.float32)
    a = bcsr_from_dense(a_dense, (8, 8))
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    useful = spmm_ops.flops(a, n)

    t_ref = time_fn(lambda b_: spmm_ops.spmm(a, b_, interpret=True), b)
    out_ref = np.asarray(spmm_ops.spmm(a, b, interpret=True))
    scale = float(np.abs(out_ref).max()) or 1.0
    points = {"f32": {"time_us": t_ref * 1e6,
                      "gflops": useful / t_ref / 1e9,
                      "max_abs_err": 0.0, "rel_err": 0.0,
                      "nnzb": int(a.nnzb)}}
    for name in QUANT_NAMES:
        aq = a.quantize(name)
        t = time_fn(lambda b_: spmm_ops.spmm(aq, b_, interpret=True), b)
        out_q = np.asarray(spmm_ops.spmm(aq, b, interpret=True))
        # BlockQuant bit-identity contract: the in-kernel dequant must match
        # dequantizing on host and running the wide kernel exactly
        out_dq = np.asarray(spmm_ops.spmm(aq.dequantize(), b, interpret=True))
        err = float(np.abs(out_q - out_ref).max())
        points[name] = {
            "time_us": t * 1e6,
            "gflops": useful / t / 1e9,
            "max_abs_err": err,
            "rel_err": err / scale,
            "bit_identical_vs_dequant_ref": bool((out_q == out_dq).all()),
            "nnzb": int(aq.nnzb),
        }
    return {"case": {"m": m, "k": k, "n": n, "block": [8, 8],
                     "density": density},
            "points": points}


def _serving_sweep(*, smoke: bool) -> dict:
    """Quantized experts + quantized KV through ServeLoop vs the f32 loop."""
    from benchmarks.bench_serve import TINY
    from repro.models import model as M_
    from repro.launch.serve import ServeLoop

    cfg = TINY
    B, P, G = (2, 8, 6) if smoke else (4, 16, 12)
    max_seq = P + G
    params = M_.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                 cfg.vocab_size)

    def first_step_logits(kv_quant):
        """Prefill + one decode step; returns that step's logits (the
        tolerance-bounded part of the serving contract -- later steps
        compound through token feedback)."""
        logits, cache, pos = M_.prefill(params, prompts, cfg,
                                        max_seq=max_seq,
                                        cache_dtype=jnp.float32,
                                        kv_quant=kv_quant)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        lg, _ = M_.decode_step_layered(params, cfg, cache, int(pos), tok)
        return np.asarray(lg)

    base_loop = ServeLoop(params, cfg, max_seq=max_seq)
    base_tokens = np.asarray(base_loop.run(prompts, G))
    base_summary = base_loop.summary()
    lg_ref = first_step_logits(None)
    scale = float(np.abs(lg_ref).max()) or 1.0

    out = {"config": {"arch": cfg.name, "batch": B, "prompt_len": P,
                      "gen": G},
           "f32": {"decode_tok_per_s":
                   base_summary.get("decode", {}).get("tok_per_s", 0.0)}}
    for name in QUANT_NAMES:
        loop = ServeLoop(params, cfg, max_seq=max_seq,
                         quantize_experts=name, kv_quant=name)
        gen = np.asarray(loop.run(prompts, G))
        s = loop.summary()
        lg = first_step_logits(name)
        err = float(np.abs(lg - lg_ref).max())
        out[name] = {
            "decode_tok_per_s": s.get("decode", {}).get("tok_per_s", 0.0),
            "prefill_ms": s["prefill"]["seconds"] * 1e3,
            "tokens_match_frac": float((gen == base_tokens).mean()),
            "first_decode_logit_max_abs_err": err,
            "first_decode_logit_rel_err": err / scale,
        }
    return out


def sweep(*, smoke: bool = False) -> dict:
    """The measured narrow-precision payload (BENCH_precision.json body);
    importable by the bench-tier smoke test."""
    return {
        "ladder_rows": _ladder_rows(),
        "spmm": _spmm_sweep(smoke=smoke),
        "serving": _serving_sweep(smoke=smoke),
    }


def _sweep_rows(payload: dict) -> list:
    rows = list(payload["ladder_rows"])
    for name, p in payload["spmm"]["points"].items():
        rows.append(row(
            f"precision/spmm/{name}", p["time_us"],
            f"gflops={p['gflops']:.3f};rel_err={p['rel_err']:.2e}"))
    for name in QUANT_NAMES:
        s = payload["serving"][name]
        rows.append(row(
            f"precision/serve/{name}", 0.0,
            f"decode_tok_per_s={s['decode_tok_per_s']:.1f};"
            f"tokens_match_frac={s['tokens_match_frac']:.2f};"
            f"logit_rel_err={s['first_decode_logit_rel_err']:.2e}"))
    return rows


def run() -> list:
    return _sweep_rows(sweep(smoke=True))


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    payload = sweep(smoke=args.smoke)
    rows = _sweep_rows(payload)
    payload["rows"] = rows
    path = emit_bench("precision", payload)
    print("\n".join(rows))
    print(f"# wrote {path}")


if __name__ == "__main__":
    main()
