"""Paper Tab. 1 / multi-precision ladder: widening matmul at f32/bf16/fp8.

Occamy's FP64/32/16/8 SIMD ladder maps to the v5e MXU's f32/bf16/fp8 modes
(DESIGN.md S2.1): each narrowing step doubles peak FLOP/s; accumulation
always widens to f32 (the ExSdotp pattern). CPU wall times are emulation
artifacts for narrow types; the TPU-projected peaks are the Tab. 1 row.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import PEAK_FLOPS, row, time_fn
from repro.core.precision import LADDER, PEAK_MULTIPLIER, policy

M = N = K = 1024


def run() -> list:
    rng = np.random.default_rng(0)
    rows = []
    a32 = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    b32 = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    flops = 2 * M * N * K
    for name in LADDER:
        pol = policy(name)

        @jax.jit
        def mm(a, b, pol=pol):
            return pol.dot(a, b)

        t = time_fn(mm, a32, b32)
        out = mm(a32, b32)
        assert out.dtype == jnp.float32, "accumulation must widen to f32"
        tpu_peak = PEAK_FLOPS["f32"] * PEAK_MULTIPLIER[name]
        rows.append(row(
            f"precision/{name}/widening_matmul", t * 1e6,
            f"cpu_gflops={flops / t / 1e9:.2f};"
            f"tpu_peak_tflops={tpu_peak / 1e12:.0f};"
            f"tpu_time_at_peak_us={flops / tpu_peak * 1e6:.2f};"
            f"accum=f32"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
