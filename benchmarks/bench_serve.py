"""Continuous-batching serving benchmark: a synthetic many-user trace
through ``launch.serve.ServeScheduler``.

The serving analogue of Occamy keeping 48 clusters fed: the scheduler must
keep the compiled two-phase hot path busy while the *population* of
requests changes -- prompts of mixed length arrive over time, finished
sequences evict between decode steps, queued prompts prefill into the
freed slots.  What this measures (and records in ``BENCH_serve.json``):

* **tok/s** of the batched decode phase (emitted tokens / decode seconds),
  plus end-to-end wall time over the whole trace.
* **per-token latency p50/p99** -- each generated token's latency is the
  wall time of the step that emitted it (the prefill pass for a request's
  first token, the shared batched decode step after), so the percentiles
  reflect what a *user* of the multi-tenant frontend sees, including the
  steps where their token shared the batch with other tenants' work.
* **first-token latency p50/p99** -- submit-to-first-token, queueing
  included.
* **recompile accounting** -- the distinct batch buckets and (two-phase)
  nnzb buckets observed, and the phase-2 compile-signature count, which
  the batch-bucket x nnzb-bucket law bounds (asserted by the bench-tier
  smoke test, ``tests/test_bench_smoke.py``).
* **serial-vs-pipelined A/B** -- each backend runs the same trace at
  ``pipeline_depth=0`` (serial, every phase blocks) and ``=1`` (route
  dispatched one program ahead, executes left in flight, sampling on
  device); the ``ab`` row records decode tok/s for both, p50/p99 token
  latency for both, the fraction of host-route time hidden behind an
  in-flight execute, and that the two runs emitted identical tokens.
* **healthy-vs-faulty A/B** (``--fault-rate R`` with R > 0) -- the same
  trace re-runs pipelined under a seeded ``FaultPlan.random`` that
  poisons/excepts a fraction of requests; the ``fault`` row records the
  faulty run's decode tok/s next to the healthy one, the
  finished/failed/shed/retry counts, how many injected faults actually
  triggered, and that every *surviving* request emitted tokens
  bit-identical to its healthy-run counterpart (the isolation contract
  of the resilience layer).

Run modes:
  python benchmarks/bench_serve.py                 # smoke-scout trace
  python benchmarks/bench_serve.py --smoke         # tiny config, CI guard
  python benchmarks/bench_serve.py --fault-rate .3 # + resilience A/B row
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import numpy as np

from benchmarks.common import emit_bench, row
from repro.models import model as M
from repro.models.config import ArchConfig
from repro.launch.serve import ServeScheduler

# tiny attn+moe config for --smoke: seconds on interpret-mode CPU
TINY = ArchConfig(
    name="tiny-serve-bench", family="moe", d_model=32, n_heads=2,
    n_kv_heads=1, d_ff=48, vocab_size=64, block_unit=("attn", "attn+moe"),
    n_repeats=2, head_dim=16, n_experts=4, top_k=1, capacity_factor=1.0,
    moe_shared_expert=True, policy="f32")


def synth_trace(n_requests: int, *, prompt_lo: int, prompt_hi: int,
                gen_lo: int, gen_hi: int, vocab: int, arrival_every: int,
                seed: int = 0) -> List[Tuple[int, np.ndarray, int]]:
    """A deterministic many-user trace: ``n_requests`` requests with
    uniformly mixed prompt/generation lengths, arriving in pairs every
    ``arrival_every`` scheduler steps (so the batch composition keeps
    changing mid-flight).  Returns (arrival_step, prompt, max_new) tuples
    sorted by arrival."""
    rng = np.random.default_rng(seed)
    trace = []
    for i in range(n_requests):
        plen = int(rng.integers(prompt_lo, prompt_hi + 1))
        gen = int(rng.integers(gen_lo, gen_hi + 1))
        prompt = rng.integers(0, vocab, plen).astype(np.int32)
        trace.append(((i // 2) * arrival_every, prompt, gen))
    return trace


def drive(sched: ServeScheduler,
          trace: List[Tuple[int, np.ndarray, int]]) -> dict:
    """Feed the trace into the scheduler at its arrival steps and run to
    drain; returns the scheduler summary + trace-level aggregates."""
    import time

    pending = sorted(trace, key=lambda t: t[0])
    t0 = time.monotonic()
    while pending or sched.has_work():
        while pending and pending[0][0] <= sched.step_idx:
            _, prompt, gen = pending.pop(0)
            sched.submit(prompt, gen)
        sched.step()
    wall = time.monotonic() - t0
    s = sched.summary()
    s["trace"] = {
        "requests": len(trace),
        "steps": sched.step_idx,
        "wall_seconds": wall,
        "prompt_tokens": int(sum(len(p) for _, p, _ in trace)),
        "generated_tokens": int(sum(len(r.tokens) for r in sched.finished)),
    }
    return s


def run(*, smoke: bool = False, dispatch: Optional[str] = None,
        fault_rate: float = 0.0) -> dict:
    """The benchmark body; importable by the bench-tier smoke test."""
    if smoke:
        cfg, max_seq, slots = TINY, 24, 2
        trace_kw = dict(n_requests=6, prompt_lo=4, prompt_hi=8, gen_lo=3,
                        gen_hi=6, vocab=cfg.vocab_size, arrival_every=2)
    else:
        from repro.configs import get_smoke
        cfg = get_smoke("llama4-scout-17b-a16e")
        max_seq, slots = 48, 4
        trace_kw = dict(n_requests=12, prompt_lo=8, prompt_hi=24, gen_lo=8,
                        gen_hi=16, vocab=cfg.vocab_size, arrival_every=3)
    if dispatch is not None:
        cfg = dataclasses.replace(cfg, moe_dispatch=dispatch)

    params = M.init_params(jax.random.PRNGKey(0), cfg)
    out = {"config": {"arch": cfg.name, "max_seq": max_seq, "slots": slots,
                      **{k: v for k, v in trace_kw.items() if k != "vocab"}}}
    for backend in ("gather", "bcsr"):
        # serial-vs-pipelined A/B: the same trace through pipeline_depth 0
        # (every phase blocks, the pre-PR-7 loop) and 1 (route-ahead fused
        # programs, in-flight executes, on-device sampling) -- the contract
        # is identical tokens, lower decode wall
        per_depth, tokens = {}, {}
        for depth, label in ((0, "serial"), (1, "pipelined")):
            sched = ServeScheduler(params, cfg, max_seq=max_seq,
                                   max_slots=slots, dispatch=backend,
                                   pipeline_depth=depth)
            s = drive(sched, synth_trace(**trace_kw))
            entry = {
                "two_phase": sched.two_phase,
                "pipeline_depth": depth,
                "decode_tok_per_s": s.get("decode", {}).get("tok_per_s",
                                                            0.0),
                "token_latency_ms": s["token_latency_ms"],
                "first_token_ms": s["first_token_ms"],
                "batch_buckets": s["batch_buckets"],
                "trace": s["trace"],
                "requests_finished": s["requests"]["finished"],
                "timing": s.get("timing", {}),
            }
            if sched.two_phase:
                # the bucket law: phase-2 signatures are bounded by the
                # product of observed batch buckets, nnzb buckets, and token
                # shapes (decode S=1 + one per distinct prompt length)
                prompt_shapes = len({len(p) for _, p, _ in
                                     synth_trace(**trace_kw)}) + 1
                entry.update(
                    nnzb_buckets=s["nnzb_buckets"],
                    compile_signatures=s["compile_signatures"],
                    signature_bound=(len(s["batch_buckets"]) + 1)
                    * max(1, len(s["nnzb_buckets"])) * prompt_shapes)
            per_depth[label] = entry
            tokens[label] = {r.uid: list(map(int, r.tokens))
                             for r in sched.finished}
        ser, pip = per_depth["serial"], per_depth["pipelined"]
        # the serial entry stays the backend's top-level schema (the
        # pre-PR-7 layout); the pipelined run and the A/B row ride under it
        e = dict(ser)
        e["pipelined"] = pip
        e["ab"] = {
            "serial_tok_per_s": ser["decode_tok_per_s"],
            "pipelined_tok_per_s": pip["decode_tok_per_s"],
            "decode_speedup": (pip["decode_tok_per_s"]
                               / ser["decode_tok_per_s"]
                               if ser["decode_tok_per_s"] else 0.0),
            "serial_p50_ms": ser["token_latency_ms"]["p50"],
            "pipelined_p50_ms": pip["token_latency_ms"]["p50"],
            "serial_p99_ms": ser["token_latency_ms"]["p99"],
            "pipelined_p99_ms": pip["token_latency_ms"]["p99"],
            "route_hidden_frac": pip["timing"].get("route_hidden_frac",
                                                   0.0),
            "tokens_match": tokens["serial"] == tokens["pipelined"],
        }
        if fault_rate > 0:
            # healthy-vs-faulty A/B: the same pipelined trace under a
            # seeded random fault plan -- survivors must emit the same
            # tokens as in the healthy run (per-request isolation)
            from repro.runtime import resilience as R

            uids = list(range(trace_kw["n_requests"]))
            plan = R.FaultPlan.random(17, uids, fault_rate)
            sched = ServeScheduler(params, cfg, max_seq=max_seq,
                                   max_slots=slots, dispatch=backend,
                                   pipeline_depth=1, fault_plan=plan)
            fs = drive(sched, synth_trace(**trace_kw))
            healthy = tokens["pipelined"]
            survivors = {r.uid: list(map(int, r.tokens))
                         for r in sched.finished}
            fr = fs["requests"]
            e["fault"] = {
                "fault_rate": fault_rate,
                "faults_injected": len(plan.specs),
                "faults_triggered": len(plan.triggered),
                "healthy_tok_per_s": pip["decode_tok_per_s"],
                "faulty_tok_per_s": fs.get("decode", {}).get("tok_per_s",
                                                             0.0),
                "finished": fr["finished"],
                "failed": fr["failed"],
                "shed": fr["shed"],
                "retries": fr["retries"],
                "ladder": fs["health"]["ladder"],
                "survivor_tokens_match": all(
                    survivors[uid] == healthy[uid] for uid in survivors),
            }
        out[backend] = e
    return out


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--dispatch", choices=["gather", "bcsr"], default=None)
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="re-run the pipelined trace under a seeded random "
                         "fault plan and emit a healthy-vs-faulty A/B row")
    args = ap.parse_args()

    payload = run(smoke=args.smoke, dispatch=args.dispatch,
                  fault_rate=args.fault_rate)
    for backend in ("gather", "bcsr"):
        e = payload[backend]
        lat = e["token_latency_ms"]
        print(row(f"serve/{backend}/decode_tok_per_s",
                  e["decode_tok_per_s"],
                  f"two_phase={e['two_phase']}"))
        print(row(f"serve/{backend}/token_latency_p50_ms", lat["p50"],
                  f"p99={lat['p99']:.1f};n={lat['n']}"))
        if "compile_signatures" in e:
            print(row(f"serve/{backend}/compile_signatures",
                      e["compile_signatures"],
                      f"bound={e['signature_bound']};"
                      f"batch_buckets={e['batch_buckets']};"
                      f"nnzb_buckets={e['nnzb_buckets']}"))
        ab = e["ab"]
        print(row(f"serve/{backend}/pipelined_tok_per_s",
                  ab["pipelined_tok_per_s"],
                  f"serial={ab['serial_tok_per_s']:.1f};"
                  f"speedup={ab['decode_speedup']:.2f}x;"
                  f"p50={ab['serial_p50_ms']:.1f}->"
                  f"{ab['pipelined_p50_ms']:.1f}ms;"
                  f"p99={ab['serial_p99_ms']:.1f}->"
                  f"{ab['pipelined_p99_ms']:.1f}ms;"
                  f"route_hidden={100 * ab['route_hidden_frac']:.0f}%;"
                  f"tokens_match={ab['tokens_match']}"))
        if "fault" in e:
            fl = e["fault"]
            print(row(f"serve/{backend}/faulty_tok_per_s",
                      fl["faulty_tok_per_s"],
                      f"healthy={fl['healthy_tok_per_s']:.1f};"
                      f"rate={fl['fault_rate']};"
                      f"triggered={fl['faults_triggered']}/"
                      f"{fl['faults_injected']};"
                      f"finished={fl['finished']};failed={fl['failed']};"
                      f"shed={fl['shed']};retries={fl['retries']};"
                      f"survivors_match={fl['survivor_tokens_match']}"))
    path = emit_bench("serve", payload)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
