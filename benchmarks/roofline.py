"""Roofline aggregator: dry-run JSONs -> the EXPERIMENTS.md SRoofline table.

Per (arch x shape x mesh): the three terms in seconds, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPS ratio, peak bytes/device, and a one-line 'what would
move the dominant term' note (rule-based from the breakdown).

Usage: PYTHONPATH=src:. python -m benchmarks.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path


def note_for(rec: dict) -> str:
    r = rec["roofline"]
    dom = r["dominant"]
    coll = rec["hlo"]["collective_bytes"]
    if dom == "collective_s":
        top = max(coll, key=coll.get) if coll else "?"
        return (f"reduce {top} traffic (overlap, bf16 collectives, "
                f"shard_map attention/MoE)")
    if dom == "memory_s":
        return ("cut activation materialization (Pallas flash kernel keeps "
                "scores in VMEM; CPU lowering also upcasts bf16->f32)")
    return "compute-bound: raise MXU occupancy (larger tiles, fp8 ladder)"


def load(dir_: Path):
    recs = []
    for f in sorted(dir_.glob("*.json")):
        try:
            recs.append(json.loads(f.read_text()))
        except Exception:
            pass
    return recs


def table(recs, mesh="single", variant="base") -> str:
    rows = ["| arch | shape | compute_s | memory_s | collective_s raw/corr | dominant "
            "| model/HLO flops | peak GiB/dev | next lever |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh or r.get("variant", "base") != variant:
            continue
        rl = r["roofline"]
        ratio = r["model_flops_per_device"] / max(r["hlo"]["dot_flops"], 1.0)
        coll_c = rl.get("collective_s_tpu_corrected", rl["collective_s"])
        rows.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {rl['compute_s']:.3f} | {rl['memory_s']:.3f} "
            f"| {rl['collective_s']:.3f}/{coll_c:.3f} "
            f"| {rl['dominant'].replace('_s','')} "
            f"| {ratio:.2f} "
            f"| {r['memory']['peak_per_device'] / 2**30:.2f} "
            f"| {note_for(r)} |")
    return "\n".join(rows)


def summary(recs) -> str:
    by_key = {}
    for r in recs:
        by_key.setdefault((r["mesh"], r.get("variant", "base")), []).append(r)
    lines = []
    for (mesh, variant), rs in sorted(by_key.items()):
        n_fit = sum(1 for r in rs
                    if r["memory"]["peak_per_device"] < 16 * 2**30)
        doms = {}
        for r in rs:
            doms[r["roofline"]["dominant"]] = doms.get(
                r["roofline"]["dominant"], 0) + 1
        lines.append(f"mesh={mesh} variant={variant}: {len(rs)} cells "
                     f"compiled, {n_fit} fit in 16 GiB HBM, dominants={doms}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variant", default="base", choices=["base", "opt"])
    args = ap.parse_args()
    recs = load(Path(args.dir))
    print(summary(recs))
    print()
    print(table(recs, args.mesh, args.variant))


if __name__ == "__main__":
    main()
