"""Benchmark aggregator: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (paper mapping in DESIGN.md S8):
  Fig. 6a -> bench_stencil      Fig. 6b -> bench_spmm
  Fig. 6c -> bench_spmspm       Tab. 1  -> bench_precision
  beyond-paper (MoE-as-SpMM) -> bench_moe
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_moe, bench_precision, bench_spmm,
                            bench_spmspm, bench_stencil)
    sections = [
        ("Fig6a/stencil", bench_stencil),
        ("Fig6b/spmm", bench_spmm),
        ("Fig6c/spmspm", bench_spmspm),
        ("Tab1/precision", bench_precision),
        ("beyond/moe", bench_moe),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for title, mod in sections:
        print(f"# --- {title} ---")
        try:
            for r in mod.run():
                print(r)
        except Exception:
            failures += 1
            print(f"# SECTION FAILED: {title}", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
