"""Paper Fig. 6c: sparse x sparse matmul (SpMSpM), 1% right-matrix density.

FoM is the paper's *index comparison rate* (GCOMP/s) and comparator
utilization. 'with SU' = the tiled all-pairs comparator formulation (what
the Pallas spmspm kernel runs on the VPU: one 8x128 vector compare = 1024
index comparisons); 'without SU' = densify-then-GEMM (the no-comparator
fallback). Left matrices sweep density; right matrices are 1% random, as in
the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import VPU_COMPARE_RATE, row, time_fn
from repro.core.formats import INVALID_KEY, random_dense_sparse
from repro.kernels.spmspm import ops as spmspm_ops
from repro.kernels.spmspm.ref import spmspm_gather_baseline

R, K, C = 256, 1024, 256
LEFT_DENSITIES = [0.02, 0.05, 0.10]
RIGHT_DENSITY = 0.01  # the paper's right-matrix density


@jax.jit
def _su_allpairs(ak, av, bk, bv):
    """Tiled all-pairs index comparison + match-gated MAC (VPU comparator)."""
    eq = (ak[:, None, :, None] == bk[None, :, None, :]) & \
        (ak[:, None, :, None] != INVALID_KEY)
    prod = av[:, None, :, None] * bv[None, :, None, :]
    return jnp.where(eq, prod, 0.0).sum(axis=(2, 3))


@jax.jit
def _nosu_dense(a_dense, b_dense):
    return a_dense @ b_dense


def run() -> list:
    rng = np.random.default_rng(0)
    rows = []
    for dl in LEFT_DENSITIES:
        a = random_dense_sparse(rng, (R, K), dl)
        b = random_dense_sparse(rng, (K, C), RIGHT_DENSITY)
        ak, av = spmspm_ops.dense_to_ell_rows(a)
        bk, bv = spmspm_ops.dense_to_ell_cols(b)
        ak_, av_ = jnp.asarray(ak), jnp.asarray(av)
        bk_, bv_ = jnp.asarray(bk), jnp.asarray(bv)
        t_su = time_fn(_su_allpairs, ak_, av_, bk_, bv_)
        t_nosu = time_fn(_nosu_dense, jnp.asarray(a), jnp.asarray(b))
        st = spmspm_ops.comparison_stats(ak, bk)
        gcomp = st["issued"] / t_su / 1e9
        # TPU projection: comparisons at VPU vector-compare rate
        tpu_t = st["issued"] / VPU_COMPARE_RATE
        comp_util = st["useful_upper"] / max(st["issued"], 1)
        rows.append(row(
            f"spmspm/left{int(dl * 100)}pct/su_intersect", t_su * 1e6,
            f"gcomp_s={gcomp:.2f};match_rate={comp_util:.4f};"
            f"issued={st['issued']};tpu_comparator_s={tpu_t * 1e3:.2f}ms;"
            f"speedup_vs_dense={t_nosu / t_su:.2f}x"))
        rows.append(row(
            f"spmspm/left{int(dl * 100)}pct/noSU_dense", t_nosu * 1e6,
            f"gflops={2 * R * K * C / t_nosu / 1e9:.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
