"""Shared benchmark utilities: timing, CSV rows, TPU-referenced derivations.

This container is CPU-only, so wall-clock numbers are XLA-CPU times; they are
meaningful for *relative* comparisons (the paper's +/-SU contrast), while
TPU-absolute projections come from the roofline terms (see EXPERIMENTS.md
SRoofline). Every row carries both.
"""
from __future__ import annotations

import time
from typing import Callable

import jax

# v5e per-chip reference constants (same as launch/dryrun.py)
PEAK_FLOPS = {"f32": 98.5e12, "bf16": 197e12, "fp8_e4m3": 394e12,
              "fp8_e5m2": 394e12}
HBM_BW = 819e9
# VPU comparator reference: 8x128 lanes x ~0.94 GHz
VPU_COMPARE_RATE = 8 * 128 * 0.94e9


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time (s) of a jitted callable; blocks on results."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
