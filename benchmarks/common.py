"""Shared benchmark utilities: timing, CSV rows, TPU-referenced derivations.

This container is CPU-only, so wall-clock numbers are XLA-CPU times; they are
meaningful for *relative* comparisons (the paper's +/-SU contrast), while
TPU-absolute projections come from the roofline terms (see EXPERIMENTS.md
SRoofline). Every row carries both.

Machine-readable artifacts: :func:`emit_bench` writes ``BENCH_<name>.json``
next to this file (shapes, tok/s, stream counts, reread factors ...) so the
perf trajectory is tracked *across PRs* -- each benchmark overwrites its own
artifact, and diffs of the JSON are the regression record.
"""
from __future__ import annotations

import json
import os
import platform
import time
from typing import Any, Callable, Dict

import jax

# v5e per-chip reference constants (same as launch/dryrun.py)
PEAK_FLOPS = {"f32": 98.5e12, "bf16": 197e12, "fp8_e4m3": 394e12,
              "fp8_e5m2": 394e12}
HBM_BW = 819e9
# VPU comparator reference: 8x128 lanes x ~0.94 GHz
VPU_COMPARE_RATE = 8 * 128 * 0.94e9


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time (s) of a jitted callable; blocks on results."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"


BENCH_DIR = os.path.dirname(os.path.abspath(__file__))


def emit_bench(name: str, payload: Dict[str, Any], *,
               directory: str | None = None) -> str:
    """Write ``BENCH_<name>.json``: the machine-readable benchmark artifact.

    ``payload`` is the benchmark's own schema (shapes, timings, stream
    counts, reread factors); this only adds the environment header every
    artifact shares.  Returns the written path.  Values must be
    JSON-serializable -- numpy scalars are coerced."""
    def coerce(v):
        if isinstance(v, dict):
            return {str(k): coerce(x) for k, x in v.items()}
        if isinstance(v, (list, tuple)):
            return [coerce(x) for x in v]
        if hasattr(v, "item") and not isinstance(v, (str, bytes)):
            try:
                return v.item()
            except Exception:
                return str(v)
        return v

    doc = {"bench": name,
           "backend": jax.default_backend(),
           "device_count": jax.device_count(),
           "jax_version": jax.__version__,
           "platform": platform.platform(),
           **coerce(payload)}
    path = os.path.join(directory or BENCH_DIR, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=False)
        f.write("\n")
    return path
