"""Paper Fig. 6b: sparse-dense matrix multiply (SpMM) with / without SUs.

Three variants, mirroring the paper's axes:
* ``su_bcsr``  -- the SU formulation: the block-column index stream drives
  block gathers of the dense operand + back-to-back block GEMMs (what the
  Pallas kernel executes tile-wise on TPU).
* ``noSU_csr`` -- the scalar-ISA analogue: element-granular CSR with one
  explicit gather per nonzero + segment-sum (address arithmetic in code).
* ``dense``    -- dense GEMM reference (utilization denominator).

The paper's matrices are SuiteSparse; offline stand-ins sweep the same
structure axes (uniform / banded / power-law). FoMs: useful GFLOP/s,
+/-SU speedup (paper: 4.6x), utilization vs dense peak (paper: 42%).
Run modes (``python benchmarks/bench_spmm.py [--shard] [--batched]``):
* default     -- single-device variants below.
* ``--shard``   -- the sharded engine (repro.kernels.engine) on a 1-D mesh
  of virtual CPU devices (or real devices when present): N-partitioned
  SpMM + column-partitioned SpMSpM, vs. their single-device twins.
* ``--batched`` -- BatchedBCSR x dense through the vmapped kernel vs. a
  python loop over per-matrix calls (the dispatch-overhead contrast).
"""
from __future__ import annotations

import sys

if __name__ == "__main__" and "--shard" in sys.argv:
    # Must precede the first jax backend touch: fake a 4-device host.
    from repro.kernels.engine import ensure_virtual_devices
    ensure_virtual_devices(4)

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import PEAK_FLOPS, emit_bench, row, time_fn
from repro.core.formats import (banded_sparse, bcsr_from_dense, csr_from_dense,
                                powerlaw_sparse, random_dense_sparse)
from repro.kernels.spmm import ops as spmm_ops
from repro.kernels.spmm.kernel import stream_walks

M, K, N = 1024, 1024, 512


def _block_uniform(rng, shape, density, block=(8, 8)):
    """Uniform sparsity at BLOCK granularity: the structured case the TPU
    re-blocking (DESIGN.md S2.2) is built for."""
    gm, gn = shape[0] // block[0], shape[1] // block[1]
    mask = np.kron(rng.random((gm, gn)) < density,
                   np.ones(block, bool))
    return np.where(mask, rng.standard_normal(shape), 0).astype(np.float32)


import numpy as np  # noqa: E402  (used by _block_uniform)

CASES = [
    ("uniform_1pct", lambda rng: random_dense_sparse(rng, (M, K), 0.01)),
    ("uniform_5pct", lambda rng: random_dense_sparse(rng, (M, K), 0.05)),
    ("blockuniform_5pct", lambda rng: _block_uniform(rng, (M, K), 0.05)),
    ("blockuniform_20pct", lambda rng: _block_uniform(rng, (M, K), 0.20)),
    ("banded_bw16", lambda rng: banded_sparse(rng, (M, K), 16)),
    ("powerlaw_5pct", lambda rng: powerlaw_sparse(rng, (M, K), 0.05)),
]


@jax.jit
def _su_bcsr(block_rows, block_cols, blocks, b):
    """Block index stream -> gather dense K-tiles -> batched GEMM -> scatter."""
    nnzb, bm, bk = blocks.shape
    K_, N_ = b.shape
    tiles = b.reshape(K_ // bk, bk, N_)
    gathered = jnp.take(tiles, block_cols, axis=0)            # SU indirection
    partial = jnp.einsum("zmk,zkn->zmn", blocks, gathered,
                         preferred_element_type=jnp.float32)
    out = jnp.zeros((M // bm, bm, N_), jnp.float32)
    return out.at[block_rows].add(partial).reshape(M, N_)


@jax.jit
def _nosu_csr(indptr, indices, values, b):
    """Element-granular gather + segment-sum (the scalar-code analogue)."""
    rows = jnp.repeat(jnp.arange(M, dtype=jnp.int32), jnp.diff(indptr),
                      total_repeat_length=indices.shape[0])
    gathered = jnp.take(b, indices, axis=0) * values[:, None]
    return jnp.zeros((M, b.shape[1]), jnp.float32).at[rows].add(gathered)


@jax.jit
def _dense(a, b):
    return a @ b


def run_sharded() -> list:
    """--shard: the sharded engine end-to-end on an n-device mesh."""
    from repro.core.formats import batched_bcsr_from_dense
    from repro.kernels import engine

    rng = np.random.default_rng(0)
    rows = []
    n_dev = jax.device_count()
    mesh = jax.make_mesh((n_dev,), ("data",))
    # Interpret-mode kernels pay a large per-grid-step emulation cost on
    # CPU, so the sharded demo runs reduced shapes; relative numbers (and
    # the end-to-end engine path) are what this mode exercises.
    Ms, Ks, Ns = 256, 256, 512
    b = jnp.asarray(rng.standard_normal((Ks, Ns)), jnp.float32)

    shard_cases = [
        ("blockuniform_5pct", _block_uniform(rng, (Ms, Ks), 0.05)),
        ("banded_bw16", banded_sparse(rng, (Ms, Ks), 16)),
    ]
    for name, a_dense in shard_cases:
        a = bcsr_from_dense(a_dense, (8, 8))
        t_one = time_fn(lambda: spmm_ops.spmm(a, b, bn=128, interpret=True))
        t_shard = time_fn(lambda: engine.shard_spmm(a, b, mesh=mesh))
        useful = spmm_ops.flops(a, Ns)
        rows.append(row(
            f"spmm/{name}/sharded_x{n_dev}", t_shard * 1e6,
            f"useful_gflops={useful / t_shard / 1e9:.2f};"
            f"speedup_vs_1dev={t_one / t_shard:.2f}x;devices={n_dev}"))

    # Batched MoE-style dispatch: 8 expert matrices, one token block.
    stack = np.stack([_block_uniform(rng, (256, 256), 0.05)
                      for _ in range(8)])
    ab = batched_bcsr_from_dense(stack, (8, 8))
    db = jnp.asarray(rng.standard_normal((8, 256, 256)), jnp.float32)
    t_b = time_fn(lambda: engine.shard_spmm_batched(ab, db, mesh=mesh))
    rows.append(row(f"spmm/batched8_sharded_x{n_dev}", t_b * 1e6,
                    f"useful_flops={spmm_ops.flops(ab, 256)};"
                    f"block_density={ab.density():.3f}"))

    # Sharded SpMSpM (column-partitioned B streams).
    from repro.kernels.spmspm import ops as spmspm_ops
    left = random_dense_sparse(rng, (64, 512), 0.1)
    right = random_dense_sparse(rng, (512, 64), 0.01)
    ak, av = spmspm_ops.dense_to_ell_rows(left)
    bk, bv = spmspm_ops.dense_to_ell_cols(right)
    t_ss = time_fn(lambda: engine.shard_spmspm(ak, av, bk, bv, mesh=mesh))
    rows.append(row(f"spmspm/sharded_x{n_dev}", t_ss * 1e6,
                    f"devices={n_dev}"))
    return rows


def run_batched() -> list:
    """--batched: vmapped batched kernel vs. a python loop of single calls."""
    from repro.core.formats import batched_bcsr_from_dense

    rng = np.random.default_rng(0)
    rows = []
    B = 8
    stack = np.stack([_block_uniform(rng, (256, 256), 0.05)
                      for _ in range(B)])
    a = batched_bcsr_from_dense(stack, (8, 8))
    d = jnp.asarray(rng.standard_normal((B, 256, 128)), jnp.float32)
    t_batched = time_fn(lambda: spmm_ops.spmm_batched(a, d, interpret=True))

    def looped():
        return [spmm_ops.spmm(a[i], d[i], interpret=True) for i in range(B)]

    t_loop = time_fn(looped)
    useful = spmm_ops.flops(a, 128)
    rows.append(row(f"spmm/batched{B}_vmap", t_batched * 1e6,
                    f"useful_flops={useful};"
                    f"speedup_vs_loop={t_loop / t_batched:.2f}x"))
    rows.append(row(f"spmm/batched{B}_loop", t_loop * 1e6, ""))
    return rows


def run_residency(bench_json: dict) -> list:
    """Multi-tile output residency: ``nt`` N-tiles of the output row stay
    VMEM-resident per walk of the index/block stream, so the stream reread
    factor drops from ``N/bn`` to ``N/(nt*bn)``.  Structural counts come
    from ``kernel.stream_walks`` (exact, backend-independent); wall times
    are interpret-mode (relative only).  Results feed BENCH_spmm.json."""
    rng = np.random.default_rng(0)
    rows = []
    bn = 128
    res_cases = [
        ("blockuniform_5pct", _block_uniform(rng, (M, K), 0.05)),
        ("banded_bw16", banded_sparse(rng, (M, K), 16)),
    ]
    b = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    bench_json["residency"] = {"shapes": {"M": M, "K": K, "N": N,
                                          "block": [8, 8], "bn": bn},
                               "cases": {}}
    for name, a_dense in res_cases:
        a = bcsr_from_dense(a_dense, (8, 8))
        case = {"nnzb": int(a.nnzb)}
        ref = None
        for nt in (1, 2, 4):
            t = time_fn(lambda nt=nt: spmm_ops.spmm(a, b, bn=bn, nt=nt,
                                                    interpret=True))
            walks = stream_walks(N, bn, nt)
            out = np.asarray(spmm_ops.spmm(a, b, bn=bn, nt=nt,
                                           interpret=True))
            if ref is None:
                ref = out
            case[f"nt{nt}"] = {
                "t_us": t * 1e6,
                "stream_walks": walks,
                "stream_blocks_read": walks * int(a.nnzb),
                "bit_identical_to_nt1": bool((out == ref).all()),
            }
            rows.append(row(
                f"spmm/{name}/residency_nt{nt}", t * 1e6,
                f"stream_walks={walks};"
                f"reread_factor={walks};"
                f"bit_identical={(out == ref).all()}"))
        case["reread_reduction_nt4_vs_nt1"] = (
            case["nt1"]["stream_walks"] / case["nt4"]["stream_walks"])
        bench_json["residency"]["cases"][name] = case
    return rows


def run() -> list:
    rng = np.random.default_rng(0)
    rows = []
    b = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    for name, gen in CASES:
        a_dense = gen(rng)
        a = bcsr_from_dense(a_dense, (8, 8))
        csr = csr_from_dense(a_dense)
        t_su = time_fn(_su_bcsr, a.block_rows, a.block_cols, a.blocks, b)
        t_nosu = time_fn(_nosu_csr, csr.indptr, csr.indices, csr.values, b)
        t_dense = time_fn(_dense, jnp.asarray(a_dense), b)
        useful = 2 * csr.nnz * N
        stream = spmm_ops.flops(a, N)  # includes block zero-padding work
        rows.append(row(
            f"spmm/{name}/su_bcsr", t_su * 1e6,
            f"useful_gflops={useful / t_su / 1e9:.2f};"
            f"speedup_vs_noSU={t_nosu / t_su:.2f}x;"
            f"block_density={a.density():.3f};"
            f"stream_efficiency={useful / max(stream, 1):.2f}"))
        rows.append(row(f"spmm/{name}/noSU_csr", t_nosu * 1e6,
                        f"useful_gflops={useful / t_nosu / 1e9:.2f}"))
        rows.append(row(f"spmm/{name}/dense", t_dense * 1e6,
                        f"gflops={2 * M * K * N / t_dense / 1e9:.2f};"
                        f"util_of_dense={(useful / t_su) / (2 * M * K * N / t_dense):.2f}"))
    return rows


if __name__ == "__main__":
    if "--shard" in sys.argv:
        print("\n".join(run_sharded()))
    elif "--batched" in sys.argv:
        print("\n".join(run_batched()))
    else:
        bench_json: dict = {}
        rows = run()
        rows += run_residency(bench_json)
        bench_json["rows"] = rows
        path = emit_bench("spmm", bench_json)
        print("\n".join(rows))
        print(f"# wrote {path}")
