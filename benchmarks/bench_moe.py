"""Beyond-paper: MoE dispatch as SpMM (the SU technique inside the LM stack).

Compares expert dispatch formulations on a Scout-like layer:
* ``dispatch=gather`` -- index-stream dispatch (gather by slot; the default
  production backend in repro.models.moe, SU indirection).
* ``dispatch=bcsr``   -- the same layer with the dispatch matrix built as a
  :class:`~repro.core.formats.BatchedBCSR` and run through
  ``engine.shard_spmm_batched`` / the SpMM Pallas kernel (interpret mode on
  CPU; correctness + stream accounting).  The chosen tiles are registered
  in ``kernels.tuning`` so the production path picks them up.
* ``onehot_einsum`` -- dense one-hot dispatch matmul (the no-SU analogue;
  O(T*E*C*d) instead of O(T*d)).
* ``bcsr_kernel`` / ``bcsr_batched`` -- raw dispatch-matrix x dense through
  the (batched) SpMM kernel outside the layer, for stream accounting.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit_bench, row, time_fn
from repro.configs import get_smoke
from repro.core.formats import batched_bcsr_from_dense, bcsr_from_dense
from repro.kernels import tuning
from repro.kernels.spmm import ops as spmm_ops
from repro.models import moe as moe_mod
from repro.models import model as M
from repro.models.config import ArchConfig

T, D, E, CF = 4096, 256, 16, 1.25
FF = 512
# reduced shape for the in-layer bcsr backend (interpret-mode kernel)
TB, DB = 512, 128


def run_host_dispatch(bench_json: dict) -> list:
    """The decode-step host-dispatch tax, before/after PR 5.

    Two A/Bs, both at decode shapes:
    * **route phase**: PR 3 ran phase-1 routing op-by-op eagerly; it is now
      one jitted program (``moe._route_phase1_jit``) plus the host stream
      compaction.
    * **layered decode step**: PR 3's ``decode_step_layered`` called every
      block eagerly; layers now run as cached jitted steps.  The eager twin
      below reproduces the PR-3 body verbatim (``apply_block`` /
      ``_decode_block_attn`` op-by-op) on the same model/cache.
    """
    rng = np.random.default_rng(0)
    rows = []
    cfg_b = dataclasses.replace(
        get_smoke("llama4-scout-17b-a16e"), d_model=DB, d_ff=FF, n_experts=E,
        capacity_factor=CF, moe_shared_expert=False)
    params_b = moe_mod.init_moe(jax.random.PRNGKey(0), cfg_b)
    Bd = 4
    x1 = jnp.asarray(rng.standard_normal((Bd, 1, DB)), jnp.float32)
    counts0 = jnp.zeros((Bd, E), jnp.int32)
    pos0 = 7
    C1 = moe_mod.dispatch_capacity(1, cfg_b, pos0=pos0)

    def route_eager_pr3():
        # the PR-3 phase 1: eager op-by-op router + slot cumsums
        r = moe_mod.route_tokens(params_b["router"], x1, cfg_b,
                                 counts=counts0, pos0=pos0)
        return jnp.where(r.keep, r.expert_id * C1 + r.within, E * C1)

    def route_jit():
        return moe_mod._route_phase1_jit(
            params_b["router"], x1, cfg_b, counts0,
            jnp.asarray(pos0, jnp.int32), C1)[3]

    t_eager = time_fn(route_eager_pr3)
    t_jit = time_fn(route_jit)
    rows.append(row("moe/route_host_dispatch(eager_pr3)", t_eager * 1e6,
                    f"tokens={Bd}x1;experts={E}"))
    rows.append(row("moe/route_host_dispatch(jit)", t_jit * 1e6,
                    f"speedup_vs_pr3={t_eager / t_jit:.2f}x"))

    # --- layered decode step: cached jitted layers vs the PR-3 eager body --
    tiny = ArchConfig(
        name="bench-moe-tiny", family="moe", d_model=32, n_heads=2,
        n_kv_heads=1, d_ff=48, vocab_size=64,
        block_unit=("attn", "attn+moe"), n_repeats=2, head_dim=16,
        n_experts=4, top_k=1, capacity_factor=1.0, moe_shared_expert=True,
        policy="f32")
    params_t = M.init_params(jax.random.PRNGKey(0), tiny)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 tiny.vocab_size)
    logits, cache, pos = M.prefill(params_t, prompts, tiny, max_seq=16,
                                   cache_dtype=jnp.float32)
    tok = jnp.argmax(logits[:, -1, :tiny.vocab_size],
                     axis=-1)[:, None].astype(jnp.int32)
    pos = int(pos)

    def step_jit_layers():
        lg, _ = M.decode_step_layered(params_t, tiny, cache, pos, tok,
                                      dtype=jnp.float32)
        return lg

    def step_eager_pr3():
        # PR-3 decode_step_layered body, verbatim: every block op-by-op
        x = jnp.take(params_t["embed"], tok, axis=0).astype(jnp.float32)
        take = lambda tree, i: jax.tree.map(lambda a: a[i], tree)  # noqa: E731
        for i in range(tiny.n_repeats):
            for slot, kind in enumerate(tiny.block_unit):
                p_i = take(params_t["blocks"][slot], i)
                c_i = take(cache["slots"][slot], i)
                if kind in M.ATTN_KINDS:
                    x, _ = M._decode_block_attn(kind, p_i, x, tiny, c_i, pos,
                                                jnp.float32)
                else:
                    x, _ = M.apply_block(kind, p_i, x, tiny, cache=c_i,
                                         pos=pos)
        from repro.models import layers as L
        x = L.rmsnorm(params_t["final_norm"], x, tiny.norm_eps)
        unemb = (params_t["embed"].T if tiny.tie_embeddings
                 else params_t["unembed"])
        return (x @ unemb.astype(x.dtype)).astype(jnp.float32)

    t_step_jit = time_fn(step_jit_layers)
    t_step_eager = time_fn(step_eager_pr3)
    rows.append(row("moe/decode_step_layered(eager_pr3)", t_step_eager * 1e6,
                    "layers=4;op_by_op"))
    rows.append(row("moe/decode_step_layered(jit_layers)", t_step_jit * 1e6,
                    f"speedup_vs_pr3={t_step_eager / t_step_jit:.2f}x"))
    bench_json["host_dispatch"] = {
        "route_eager_pr3_us": t_eager * 1e6,
        "route_jit_us": t_jit * 1e6,
        "route_speedup": t_eager / t_jit,
        "decode_step_eager_pr3_us": t_step_eager * 1e6,
        "decode_step_jit_layers_us": t_step_jit * 1e6,
        "decode_step_speedup": t_step_eager / t_step_jit,
        "shapes": {"route": [Bd, 1, DB], "tiny_arch": tiny.name,
                   "decode_layers": tiny.n_repeats * len(tiny.block_unit)},
    }
    return rows


def run(bench_json: dict | None = None) -> list:
    rng = np.random.default_rng(0)
    rows = []
    cfg = dataclasses.replace(
        get_smoke("llama4-scout-17b-a16e"), d_model=D, d_ff=FF, n_experts=E,
        capacity_factor=CF, moe_shared_expert=False)
    params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((1, T, D)), jnp.float32)

    su = jax.jit(lambda p, x: moe_mod.apply_moe(p, x, cfg,
                                                dispatch="gather")[0])
    t_su = time_fn(su, params, x)

    @jax.jit
    def onehot(p, x):
        xt = x.reshape(T, D)
        logits = xt @ p["router"]
        gate = jax.nn.softmax(logits, axis=-1)
        top_g, top_e = jax.lax.top_k(gate, 1)
        C = int(T / E * CF)
        onehot_te = jax.nn.one_hot(top_e[:, 0], E)             # (T, E)
        pos = (jnp.cumsum(onehot_te, axis=0) - 1) * onehot_te
        keep = (pos < C).all(axis=-1)
        disp = onehot_te[:, :, None] * jax.nn.one_hot(
            jnp.where(keep, pos.sum(-1), C).astype(jnp.int32), C + 1)[:, None, :C]
        xe = jnp.einsum("tec,td->ecd", disp, xt)               # dense dispatch
        ye = moe_mod._expert_ffn(p["experts"], xe, cfg.mlp_type)
        back = jnp.einsum("tec,ecd->td", disp, ye)
        return (back * top_g).reshape(1, T, D)

    t_oh = time_fn(onehot, params, x)

    # In-layer backend A/B on a reduced shape: same layer, gather vs the
    # dispatch matrix as BatchedBCSR through the sharded SpMM kernel.
    # Eager on purpose -- the eager path compacts the block stream to the
    # union nonzero pattern (the jit path pays the full-grid stream).
    cfg_b = dataclasses.replace(cfg, d_model=DB)
    params_b = moe_mod.init_moe(jax.random.PRNGKey(0), cfg_b)
    xb_in = jnp.asarray(rng.standard_normal((1, TB, DB)), jnp.float32)
    tiles = tuning.moe_dispatch_tiles(DB, jnp.float32)
    # pin the CPU interpret-mode row to the tiles this comparison actually
    # ran (explicit platform: never clobber the TPU row with a bn that was
    # shape-clamped to this benchmark's small d_model); keep the bucket
    # floor -- register replaces the whole row
    tuning.register("moe_dispatch", jnp.float32,
                    {"block": tiles["block"], "bn": tiles["bn"],
                     "min_bucket": tiles["min_bucket"]},
                    platform="cpu")
    gth = jax.jit(lambda p, x: moe_mod.apply_moe(p, x, cfg_b,
                                                 dispatch="gather")[0])
    t_gth = time_fn(gth, params_b, xb_in)
    t_bcsr = time_fn(
        lambda: moe_mod.apply_moe(params_b, xb_in, cfg_b, dispatch="bcsr")[0])
    ref = gth(params_b, xb_in)
    got = moe_mod.apply_moe(params_b, xb_in, cfg_b, dispatch="bcsr")[0]
    assert float(jnp.abs(ref - got).max()) == 0.0, "backends diverge"

    # Two-phase route-then-compile: the *jit* gather-vs-bcsr comparison the
    # serving loop actually runs.  Phase 1 (host routing + stream
    # compaction) is timed eagerly; phase 2 (dispatch+FFN+combine) is the
    # jit-compiled step on the bucketed stream.  The stream-size row is the
    # point: bucketed nnzb vs the full grid the single-phase jit fallback
    # pays (`moe/backend_bcsr_engine` above routes through that fallback
    # only when traced; here it ran eagerly).
    plan, info = moe_mod.route_moe(params_b, xb_in, cfg_b, dispatch="bcsr")
    t_route = time_fn(
        lambda: moe_mod.route_moe(params_b, xb_in, cfg_b,
                                  dispatch="bcsr")[0].flat_slot)
    t_exec = time_fn(
        lambda: moe_mod.execute_moe_jit(params_b, xb_in, plan, cfg_b)[0])
    got2p = moe_mod.execute_moe_jit(params_b, xb_in, plan, cfg_b)[0]
    assert float(jnp.abs(ref - got2p).max()) == 0.0, "two-phase diverges"
    # Pipelined route/execute chain (PR 7): N back-to-back two-phase layer
    # calls, blocking on every execute (the serial serving loop) vs leaving
    # one execute in flight behind the next host route (StreamPipeline
    # depth 1, the pipelined loop).  The delta is the host routing yield
    # the pipeline hides.
    from repro.kernels import engine as eng
    N_CHAIN = 8

    def chain_serial():
        out = xb_in
        for _ in range(N_CHAIN):
            plan_i, _ = moe_mod.route_moe(params_b, out, cfg_b,
                                          dispatch="bcsr")
            out, _ = moe_mod.execute_moe_jit(params_b, out, plan_i, cfg_b)
            jax.block_until_ready(out)
        return out

    def chain_pipelined():
        pipe = eng.StreamPipeline(1)
        out = xb_in
        for _ in range(N_CHAIN):
            plan_i, _ = moe_mod.route_moe(params_b, out, cfg_b,
                                          dispatch="bcsr")
            out, _ = moe_mod.execute_moe_jit(params_b, out, plan_i, cfg_b)
            pipe.push("exec", out)
        pipe.drain()
        return out

    t_chain_ser = time_fn(chain_serial)
    t_chain_pip = time_fn(chain_pipelined)
    assert float(jnp.abs(chain_serial() - chain_pipelined()).max()) == 0.0, \
        "pipelined chain diverges"
    if bench_json is not None:
        bench_json["two_phase"] = {
            "tokens": TB, "experts": E, "d_model": DB,
            "route_us": t_route * 1e6, "exec_us": t_exec * 1e6,
            "gather_jit_us": t_gth * 1e6,
            "nnzb_stream": info["nnzb_stream"],
            "nnzb_routed": info["nnzb_routed"],
            "grid_nnzb": info["grid_nnzb"],
            "stream_reduction": info["grid_nnzb"] / info["nnzb_stream"],
            "chain_layers": N_CHAIN,
            "serial_chain_us": t_chain_ser * 1e6,
            "pipelined_chain_us": t_chain_pip * 1e6,
            "overlap_speedup": t_chain_ser / t_chain_pip,
        }

    # BCSR-on-kernel: dispatch matrix (T x T permutation-ish) as block-sparse
    sel = rng.permutation(T)[: T // 4]
    disp_dense = np.zeros((T // 4 * 8 // 8 * 8, T), np.float32)
    for i, s in enumerate(sel[: disp_dense.shape[0]]):
        disp_dense[i, s] = 1.0
    a = bcsr_from_dense(disp_dense[: (T // 4) // 8 * 8], (8, 8))
    xd = jnp.asarray(rng.standard_normal((T, 128)), jnp.float32)
    t_k = time_fn(lambda: spmm_ops.spmm(a, xd, interpret=True))
    useful = spmm_ops.flops(a, 128)

    # Batched per-expert dispatch: each expert's token-selection matrix is a
    # block-sparse (C x T) gather; all E' matrices share one union index
    # stream and run in ONE spmm_batched call (the engine's batch axis).
    Eb, Cap, Tb = 4, 64, 512
    disp = np.zeros((Eb, Cap, Tb), np.float32)
    for e in range(Eb):
        picks = rng.permutation(Tb)[:Cap]
        disp[e, np.arange(Cap), picks] = 1.0
    ab = batched_bcsr_from_dense(disp, (8, 8))
    xb = jnp.asarray(rng.standard_normal((Tb, 128)), jnp.float32)
    t_bat = time_fn(lambda: spmm_ops.spmm_batched(ab, xb, interpret=True))

    rows.append(row("moe/su_gather_dispatch", t_su * 1e6,
                    f"tokens={T};experts={E};capacity_factor={CF}"))
    rows.append(row("moe/onehot_einsum_dispatch", t_oh * 1e6,
                    f"speedup_su_vs_onehot={t_oh / t_su:.2f}x"))
    rows.append(row("moe/backend_gather(jit)", t_gth * 1e6,
                    f"tokens={TB};experts={E};d={DB}"))
    rows.append(row("moe/backend_bcsr_engine(interp)", t_bcsr * 1e6,
                    f"tokens={TB};experts={E};d={DB};"
                    f"block={tiles['block']};bn={tiles['bn']};"
                    f"gather_vs_bcsr={t_bcsr / t_gth:.2f}x"))
    rows.append(row("moe/backend_bcsr_two_phase(jit)",
                    (t_route + t_exec) * 1e6,
                    f"tokens={TB};experts={E};d={DB};"
                    f"route_us={t_route*1e6:.1f};exec_us={t_exec*1e6:.1f};"
                    f"nnzb_stream={info['nnzb_stream']};"
                    f"nnzb_routed={info['nnzb_routed']};"
                    f"grid_nnzb={info['grid_nnzb']};"
                    f"stream_reduction="
                    f"{info['grid_nnzb'] / info['nnzb_stream']:.1f}x;"
                    f"jit_gather_vs_two_phase="
                    f"{(t_route + t_exec) / t_gth:.2f}x"))
    rows.append(row("moe/two_phase_chain_pipelined", t_chain_pip * 1e6,
                    f"layers={N_CHAIN};"
                    f"serial_us={t_chain_ser * 1e6:.1f};"
                    f"overlap_speedup={t_chain_ser / t_chain_pip:.2f}x"))
    rows.append(row("moe/bcsr_kernel_dispatch(interp)", t_k * 1e6,
                    f"useful_flops={useful};block_density={a.density():.4f}"))
    rows.append(row("moe/bcsr_batched_dispatch(interp)", t_bat * 1e6,
                    f"experts={Eb};useful_flops={spmm_ops.flops(ab, 128)};"
                    f"union_nnzb={ab.nnzb};block_density={ab.density():.4f}"))
    return rows


if __name__ == "__main__":
    bench_json: dict = {}
    rows = run(bench_json)
    rows += run_host_dispatch(bench_json)
    bench_json["rows"] = rows
    path = emit_bench("moe", bench_json)
    print("\n".join(rows))
    print(f"# wrote {path}")
