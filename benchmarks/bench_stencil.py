"""Paper Fig. 6a: FP64 stencils with / without SUs.

Repro mapping: 'with SU' = the streaming shifted-slice formulation (affine
streams; what the Pallas kernel implements tile-wise); 'without SU' = the
scalar-ISA analogue (explicit per-tap index arithmetic + gather). Both are
XLA-compiled; the ratio reproduces the paper's +/-SU contrast (3.9x on
j3d27pt in silicon). TPU-absolute: FLOPs / bytes / roofline utilization
derived per stencil (f32 stands in for FP64 per DESIGN.md S2.1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import HBM_BW, PEAK_FLOPS, row, time_fn
from repro.core.stencils import STENCILS, apply_gather_baseline, apply_reference
from repro.kernels.stencil import ops as stencil_ops

CASES = [
    ("j2d5pt", (1024, 1024)),
    ("j2d9pt", (1024, 1024)),
    ("j2d9pt-gol", (512, 512)),
    ("j3d7pt", (64, 64, 256)),
    ("j3d27pt", (64, 64, 256)),
]


def run() -> list:
    rng = np.random.default_rng(0)
    rows = []
    for name, interior in CASES:
        spec = STENCILS[name]
        r = spec.radius
        grid = jnp.asarray(
            rng.standard_normal([s + 2 * r for s in interior]), jnp.float32)
        su = jax.jit(functools.partial(apply_reference, spec))
        base = jax.jit(functools.partial(apply_gather_baseline, spec))
        t_su = time_fn(su, grid)
        t_base = time_fn(base, grid)
        flops = stencil_ops.flops(spec, tuple(interior))
        n = int(np.prod(interior))
        # TPU roofline: one grid read + one write per point (halo amortized),
        # taps come from VMEM -- arithmetic intensity = flops / 8 bytes.
        tpu_mem_s = (2 * 4 * n) / HBM_BW
        tpu_comp_s = flops / PEAK_FLOPS["f32"]
        util = tpu_comp_s / max(tpu_comp_s, tpu_mem_s)
        rows.append(row(
            f"stencil/{name}/su", t_su * 1e6,
            f"gflops={flops / t_su / 1e9:.2f};speedup_vs_noSU={t_base / t_su:.2f}x;"
            f"tpu_roofline_util={util:.2f};points={spec.points}"))
        rows.append(row(
            f"stencil/{name}/noSU", t_base * 1e6,
            f"gflops={flops / t_base / 1e9:.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
