"""Repo-wide pytest configuration.

Two jobs, both of which must happen before any test module imports jax:

1. Force a multi-device CPU topology (4 virtual devices) so the sharded
   sparse-engine tests exercise real ``shard_map`` partitioning on a plain
   CPU host.  Harmless for single-device tests: jit still places
   un-sharded computations on device 0.
2. Tier the suite: ``slow`` (integration / model-smoke) and ``serve``
   (full serving-loop smoke) tests are deselected by default so the tier-1
   gate (``pytest -x -q``) finishes in minutes; run them with
   ``--run-slow`` / ``--run-serve`` (or select explicitly with ``-m``).
   ``tpu`` tests are skipped unless a TPU backend is attached.
"""
import os

# Must precede the first jax backend initialization (which happens at test
# collection time via module-level PRNGKey calls in some test files).
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=4"
    ).strip()

import pytest  # noqa: E402


def pytest_addoption(parser):
    parser.addoption(
        "--run-slow", action="store_true", default=False,
        help="run tests marked slow (integration / model smoke)")
    parser.addoption(
        "--run-serve", action="store_true", default=False,
        help="run tests marked serve (full serving-loop smoke)")
    parser.addoption(
        "--run-bench", action="store_true", default=False,
        help="run tests marked bench (benchmark-harness smoke)")
    parser.addoption(
        "--run-stress", action="store_true", default=False,
        help="run tests marked stress (randomized fault/eviction "
             "resilience runs)")


def pytest_collection_modifyitems(config, items):
    import jax

    if jax.default_backend() != "tpu":
        skip_tpu = pytest.mark.skip(reason="requires a TPU backend")
        for item in items:
            if "tpu" in item.keywords:
                item.add_marker(skip_tpu)

    # Explicit opt-ins override the default deselection: --run-slow, a -m
    # marker expression, or directly naming a file / node id on the CLI
    # (`pytest tests/test_models_smoke.py::test_x` should run that test,
    # not report a green 0-test run).
    named_explicitly = any(
        arg.endswith(".py") or "::" in arg for arg in config.args)
    if config.getoption("-m") or named_explicitly:
        return
    # slow, serve, bench, and stress are independently opt-in tiers
    skip_marks = {m for m, opt in (("slow", "--run-slow"),
                                   ("serve", "--run-serve"),
                                   ("bench", "--run-bench"),
                                   ("stress", "--run-stress"))
                  if not config.getoption(opt)}
    selected = [i for i in items
                if not any(m in i.keywords for m in skip_marks)]
    deselected = [i for i in items
                  if any(m in i.keywords for m in skip_marks)]
    if deselected:
        config.hook.pytest_deselected(items=deselected)
        items[:] = selected
