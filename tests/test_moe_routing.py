"""Tier-1 MoE routing tests: prefix-stable slots, decode == prefill.

The contract under test (see models/moe.py): a token's expert slot and
keep/drop decision are pure functions of its own row's routing history --
never of batch companions or of tokens that come later.  Stepwise decode
(counts threaded through the cache) must therefore reproduce the prefill
drop set *bit-identically*, for both dispatch backends.

These run on a tiny config with capacity_factor=1.0 so drops actually
happen (the old in-batch-cumsum formulation fails all of these).
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ArchConfig
from repro.models import model as M
from repro.models import moe

TINY = ArchConfig(
    name="tiny-moe", family="moe", d_model=32, n_heads=2, n_kv_heads=1,
    d_ff=48, vocab_size=64, block_unit=("attn+moe",), n_repeats=2,
    head_dim=16, n_experts=4, top_k=1, capacity_factor=1.0,
    moe_shared_expert=True, policy="f32")

KEY = jax.random.PRNGKey(0)
BACKENDS = ("gather", "bcsr")


def _layer():
    p = moe.init_moe(KEY, TINY)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, TINY.d_model),
                          jnp.float32)
    return p, x


# ------------------------------------------------------------- routing law --

def test_prefix_capacity_is_ceil():
    # documented law: C(t) = ceil((t+1)/E * f).  int() truncation would give
    # 3 at t=9 (10 * 1.25 / 4 = 3.125) -- the old off-by-one drop.
    assert int(moe.prefix_capacity(9, 4, 1.25)) == 4
    assert int(moe.prefix_capacity(0, 4, 1.0)) == 1
    assert int(moe.prefix_capacity(7, 4, 1.0)) == 2
    # dispatch buffer bound uses the same arithmetic and never under-sizes
    assert moe.dispatch_capacity(10, dataclasses.replace(TINY,
                                                         capacity_factor=1.25)) == 4


def test_routing_is_prefix_stable_stepwise():
    """Routing all S tokens at once == one token at a time with counts
    carried -- slots, keep sets, and final occupancy all bit-identical."""
    p, x = _layer()
    full = moe.route_tokens(p["router"], x, TINY)
    assert int((~full.keep).sum()) > 0, "test config must actually drop"
    counts = None
    keeps, slots, experts = [], [], []
    for t in range(x.shape[1]):
        r = moe.route_tokens(p["router"], x[:, t:t + 1], TINY,
                             counts=counts, pos0=t)
        counts = r.new_counts
        keeps.append(r.keep[:, 0])
        slots.append(r.slot[:, 0])
        experts.append(r.expert_id[:, 0])
    np.testing.assert_array_equal(np.stack(experts, 1),
                                  np.asarray(full.expert_id))
    np.testing.assert_array_equal(np.stack(slots, 1), np.asarray(full.slot))
    np.testing.assert_array_equal(np.stack(keeps, 1), np.asarray(full.keep))
    np.testing.assert_array_equal(np.asarray(counts),
                                  np.asarray(full.new_counts))


def test_routing_ignores_batch_companions():
    """A row's decisions must not depend on which rows share the batch."""
    p, x = _layer()
    full = moe.route_tokens(p["router"], x, TINY)
    solo = moe.route_tokens(p["router"], x[1:2], TINY)
    np.testing.assert_array_equal(np.asarray(full.keep[1]),
                                  np.asarray(solo.keep[0]))
    np.testing.assert_array_equal(np.asarray(full.slot[1]),
                                  np.asarray(solo.slot[0]))


# ------------------------------------------------------------ layer parity --

@pytest.mark.parametrize("dispatch", BACKENDS)
def test_apply_moe_decode_matches_prefill(dispatch):
    p, x = _layer()
    full, full_counts = moe.apply_moe(p, x, TINY, dispatch=dispatch)
    counts, outs = None, []
    for t in range(x.shape[1]):
        o, counts = moe.apply_moe(p, x[:, t:t + 1], TINY, counts=counts,
                                  pos=jnp.asarray(t, jnp.int32),
                                  dispatch=dispatch)
        outs.append(o[:, 0])
    step = jnp.stack(outs, axis=1)
    np.testing.assert_array_equal(np.asarray(counts),
                                  np.asarray(full_counts))
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               atol=1e-6, rtol=1e-6)


def test_dispatch_backends_bit_identical():
    """The BCSR path multiplies by exact 0/1 blocks with f32 accumulation,
    so both backends must produce the same bits (swap-safe mid-deployment)."""
    p, x = _layer()
    g, _ = moe.apply_moe(p, x, TINY, dispatch="gather")
    b, _ = moe.apply_moe(p, x, TINY, dispatch="bcsr")
    np.testing.assert_array_equal(np.asarray(g), np.asarray(b))
    # and under tracing (full-grid index stream)
    bj = jax.jit(lambda p, x: moe.apply_moe(p, x, TINY, dispatch="bcsr")[0])(p, x)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(bj))


def test_moe_group_misalignment_warns_and_strict_raises():
    p, x = _layer()  # B = 2
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        moe.apply_moe(p, x, TINY, groups=3)
    assert any(issubclass(i.category, RuntimeWarning) for i in w)
    with pytest.raises(ValueError):
        moe.apply_moe(p, x,
                      dataclasses.replace(TINY, moe_strict_dispatch=True),
                      groups=3)
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # G | B: no warning
        moe.apply_moe(p, x, TINY, groups=2)


# --------------------------------------------------------------- two-phase --

@pytest.mark.parametrize("dispatch", BACKENDS)
def test_route_execute_matches_apply_moe(dispatch):
    """Phase-1 + phase-2 == the fused layer, bit-for-bit, eager AND with
    phase 2 jit-compiled (the serving configuration)."""
    p, x = _layer()
    want, want_counts = moe.apply_moe(p, x, TINY, dispatch=dispatch)
    plan, info = moe.route_moe(p, x, TINY, dispatch=dispatch)
    for ex in (moe.execute_moe, moe.execute_moe_jit):
        out, counts = ex(p, x, plan, TINY)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
        np.testing.assert_array_equal(np.asarray(counts),
                                      np.asarray(want_counts))
    assert info["backend"] == dispatch


def test_route_moe_rejects_tracers():
    """Routing under jit would force the stream back to the full grid, so
    phase 1 refuses to trace."""
    p, x = _layer()
    with pytest.raises(TypeError, match="eager phase"):
        jax.jit(lambda x: moe.route_moe(p, x, TINY, dispatch="bcsr"))(x)


def test_two_phase_stepwise_decode_matches_prefill():
    """route+execute one token at a time (counts threaded) reproduces the
    fused full-sequence layer -- the ServeLoop decode path.  Same tolerance
    as the fused stepwise test: the shared-expert MLP is evaluated on
    (B*S, d) vs (B*1, d) shapes, so bit-identity holds per-call, not
    across the step split."""
    p, x = _layer()
    want, want_counts = moe.apply_moe(p, x, TINY, dispatch="bcsr")
    counts, outs = None, []
    for t in range(x.shape[1]):
        plan, _ = moe.route_moe(p, x[:, t:t + 1], TINY, counts=counts,
                                pos=t, dispatch="bcsr")
        o, counts = moe.execute_moe_jit(p, x[:, t:t + 1], plan, TINY)
        outs.append(o[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(want), atol=1e-6, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(counts),
                                  np.asarray(want_counts))


def test_two_phase_stream_is_compacted_under_jit():
    """THE tentpole property: with phase 2 under jit, the bcsr dispatch
    stream length tracks the *routed* nonzero blocks (<= 2x, via the
    power-of-two bucket), not the E*C x T full grid the single-phase jit
    fallback pays.  Output stays bit-identical to the gather backend."""
    import dataclasses as dc
    from repro.kernels import engine, tuning

    # Long sequence, small expert capacity: most of the (slot, token) grid
    # is structurally empty, so compaction has something to win.
    cfg = dc.replace(TINY, n_experts=4, capacity_factor=1.0,
                     moe_shared_expert=False)
    p = moe.init_moe(KEY, cfg)
    S = 256
    x = jax.random.normal(jax.random.PRNGKey(5), (2, S, cfg.d_model),
                          jnp.float32)
    plan, info = moe.route_moe(p, x, cfg, dispatch="bcsr")
    assert plan.stream is not None

    # compaction: bucketed stream <= 2x covered blocks, and a real reduction
    # vs the full grid (which is what scales with E*C and T)
    assert info["nnzb_stream"] == plan.stream.nnzb
    assert info["nnzb_stream"] <= 2 * max(
        info["nnzb_covered"],
        tuning.moe_dispatch_tiles(cfg.d_model)["min_bucket"])
    assert info["nnzb_stream"] <= info["grid_nnzb"] // 2, (
        "bucketed stream should be well under the full grid here")
    assert info["nnzb_stream"] == engine.stream_bucket(
        info["nnzb_covered"],
        minimum=tuning.moe_dispatch_tiles(cfg.d_model)["min_bucket"])

    # independence of E*C: vary the expert count (4 -> 8 -> 16; the
    # capacity law keeps E*C ~ S*f, so the grid is unchanged) -- the
    # bucketed stream must track the routed blocks, staying within one
    # bucket step of the E=4 stream rather than scaling with the grid.
    for E2 in (8, 16):
        cfg2 = dc.replace(cfg, n_experts=E2)
        p2 = moe.init_moe(KEY, cfg2)
        _, info2 = moe.route_moe(p2, x, cfg2, dispatch="bcsr")
        assert info2["nnzb_stream"] <= 2 * info["nnzb_stream"]
        assert info2["nnzb_stream"] <= info2["grid_nnzb"] // 2

    # bit-identity with gather, phase 2 jitted
    want, _ = moe.apply_moe(p, x, cfg, dispatch="gather")
    got, _ = moe.execute_moe_jit(p, x, plan, cfg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_two_phase_compile_cache_is_bucketed():
    """Decode steps with different routings but one nnzb bucket share one
    phase-2 compile: the cache grows with buckets, not with steps."""
    p, x = _layer()
    n0 = moe.execute_moe_jit._cache_size()
    counts, sizes = None, set()
    for t in range(x.shape[1]):
        plan, info = moe.route_moe(p, x[:, t:t + 1], TINY, counts=counts,
                                   pos=t, dispatch="bcsr")
        _, counts = moe.execute_moe_jit(p, x[:, t:t + 1], plan, TINY)
        sizes.add((plan.capacity, plan.stream.nnzb))
    grew = moe.execute_moe_jit._cache_size() - n0
    assert grew <= len(sizes), (
        f"phase-2 recompiled {grew}x for {len(sizes)} distinct bucket "
        "signatures")


# ------------------------------------------------------------ model parity --

@pytest.mark.parametrize("dispatch", BACKENDS)
def test_model_decode_matches_prefill_tiny(dispatch):
    """Full-model parity on the tiny config, capacity drops active, both
    dispatch backends.  f32 policy + prefix-aligned decode arithmetic make
    this near-exact, so the tolerance is tight."""
    cfg = dataclasses.replace(TINY, moe_dispatch=dispatch)
    params = M.init_params(KEY, cfg)
    B, S = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab_size)
    full = M.forward(params, tokens, cfg)
    cache = M.init_cache(cfg, batch=B, max_seq=S, dtype=jnp.float32)
    outs = []
    for t in range(S):
        logits, cache = M.decode_step(params, cfg, cache,
                                      jnp.asarray(t, jnp.int32),
                                      tokens[:, t:t + 1], dtype=jnp.float32)
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=1e-4, rtol=1e-4)


def test_prefill_carries_routing_counts_into_decode():
    """prefill(prompt) -> decode must continue each expert queue where the
    prompt left it: the cache carries per-(row, expert) occupancy."""
    cfg = TINY
    params = M.init_params(KEY, cfg)
    B, S_prompt, S_gen = 1, 6, 4
    S = S_prompt + S_gen
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                                cfg.vocab_size)
    full = M.forward(params, tokens, cfg)
    logits, cache, pos = M.prefill(params, tokens[:, :S_prompt], cfg,
                                   max_seq=S, cache_dtype=jnp.float32)
    counts = cache["slots"][0]["moe"]
    assert counts.shape == (cfg.n_repeats, B, cfg.n_experts)
    assert counts.dtype == jnp.int32
    # every routed prompt token is counted, kept or dropped
    assert int(counts.sum()) == cfg.n_repeats * B * S_prompt
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, S_prompt - 1]),
                               atol=1e-4, rtol=1e-4)
    outs = []
    for t in range(S_prompt, S):
        step_logits, cache = M.decode_step(params, cfg, cache,
                                           jnp.asarray(t, jnp.int32),
                                           tokens[:, t:t + 1],
                                           dtype=jnp.float32)
        outs.append(step_logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, S_prompt:]),
                               atol=1e-4, rtol=1e-4)
