"""Serving resilience (PR 10): deterministic fault injection, per-request
isolation, retry/shed/deadline policy, and the graceful-degradation ladder.

The contract under test (see "Resilience contract" in ``tests/README.md``):

* **Survivor bit-identity.**  With any single injected fault (any stage x
  any kind), every surviving request's generated tokens are bit-identical
  to the same trace run fault-free -- on both dispatch backends, at
  pipeline depth 0 and 1.  Poison stays in its batch row (per-row
  independence of attention, prefix-stable MoE, bcsr dispatch, and
  per-request sampling keys), and host-side failures retry from untouched
  state (faults fire before any key split or cache commit).
* **Zero new host syncs.**  At depth 1 the health bits ride the existing
  per-step token fetch: exactly one ``jax.device_get`` per decode step.
* **Policy.**  Bounded exponential-backoff retries, TTFT/total deadlines
  on a fake clock, a bounded admission queue with reject / drop-oldest
  shed policies, and the kv_wide -> mask_ref -> pipeline_serial ladder.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import precision
from repro.core.masks import AttnMaskSpec
from repro.kernels import engine
from repro.launch import serve
from repro.launch.serve import ServeLoop, ServeScheduler, _percentiles_ms
from repro.models import model as M
from repro.models import moe
from repro.models.config import ArchConfig
from repro.runtime import resilience as R

TINY = ArchConfig(
    name="tiny-resilience", family="moe", d_model=32, n_heads=2,
    n_kv_heads=1, d_ff=48, vocab_size=64, block_unit=("attn", "attn+moe"),
    n_repeats=2, head_dim=16, n_experts=4, top_k=1, capacity_factor=1.0,
    moe_shared_expert=True, policy="f32")

PROMPT, GEN, MAX_SEQ = 8, 5, 16
N_REQ, SLOTS = 3, 2


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(0), TINY)


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(0)
    return [rng.integers(0, TINY.vocab_size, PROMPT) for _ in range(N_REQ)]


def _run_sched(params, prompts, *, dispatch="bcsr", depth=1, plan=None,
               kv_quant=None, temperature=0.0, **kw):
    sched = ServeScheduler(
        params, TINY, max_seq=MAX_SEQ, max_slots=SLOTS, dispatch=dispatch,
        two_phase=dispatch == "bcsr", temperature=temperature,
        cache_dtype=jnp.float32, pipeline_depth=depth, kv_quant=kv_quant,
        fault_plan=plan, **kw)
    for p in prompts:
        sched.submit(p, GEN)
    return sched, sched.run()


@pytest.fixture(scope="module")
def baselines(params, prompts):
    """Fault-free token maps per (dispatch, depth, kv_quant) combo, computed
    lazily so only combos a test actually compares against are run."""
    cache = {}

    class Lazy:
        def __getitem__(self, key):
            if key not in cache:
                dispatch, depth, kvq = key
                _, cache[key] = _run_sched(params, prompts,
                                           dispatch=dispatch, depth=depth,
                                           kv_quant=kvq)
            return cache[key]

    return Lazy()


def _assert_survivors_identical(out, base, *, failed_uids=()):
    for uid, toks in base.items():
        if uid in failed_uids:
            continue
        assert uid in out, f"survivor {uid} missing from faulted run"
        np.testing.assert_array_equal(
            out[uid], toks,
            err_msg=f"survivor {uid} tokens diverged under fault")


# --------------------------------------------------------- fault registry --

class TestFaultPlan:
    def test_spec_validation(self):
        with pytest.raises(ValueError, match="stage"):
            R.FaultSpec(stage="nope", kind="nan")
        with pytest.raises(ValueError, match="kind"):
            R.FaultSpec(stage="sample", kind="nope")
        with pytest.raises(ValueError, match="quantize"):
            R.FaultSpec(stage="quantize", kind="exception")

    def test_poison_rows(self):
        x = jnp.ones((4, 3))
        y = np.asarray(R.poison_rows(x, [1, 3], "nan"))
        assert np.isnan(y[[1, 3]]).all() and (y[[0, 2]] == 1.0).all()
        z = np.asarray(R.poison_rows(x, [0], "inf"))
        assert np.isinf(z[0]).all() and (z[1:] == 1.0).all()
        assert R.poison_rows(x, [], "nan") is x

    def test_times_and_reset(self):
        plan = R.FaultPlan.single("sample", "nan", times=2)
        x = jnp.ones((2, 4))
        for _ in range(3):
            plan.apply("sample", x, step=0)
        assert len(plan.triggered) == 2
        plan.reset()
        assert plan.triggered == [] and len(plan._armed(
            "sample", step=None, layer=0)) == 1

    def test_selectors(self):
        plan = R.FaultPlan.single("execute", "nan", uid=7, step=3)
        x = jnp.ones((2, 4))
        # wrong step: no fire
        assert plan.apply("execute", x, step=2, uids=[7, None]) is x
        # right step, uid not resident: no fire, stays armed
        assert plan.apply("execute", x, step=3, uids=[1, 2]) is x
        y = plan.apply("execute", x, step=3, uids=[1, 7])
        assert np.isnan(np.asarray(y)[1]).all()
        assert plan.triggered == [("execute", "nan", 3, (1,))]

    def test_exception_and_straggler(self):
        plan = R.FaultPlan([R.FaultSpec("route", "exception", step=1),
                            R.FaultSpec("route", "straggler", step=2,
                                        delay_s=0.0)])
        x = jnp.ones((1, 2))
        plan.apply("route", x, step=0)
        with pytest.raises(R.InjectedFault):
            plan.apply("route", x, step=1)
        plan.apply("route", x, step=2)   # sleeps 0s, logs
        kinds = [t[1] for t in plan.triggered]
        assert kinds == ["exception", "straggler"]

    def test_random_plan_seeded(self):
        uids = list(range(20))
        a = R.FaultPlan.random(5, uids, 0.4)
        b = R.FaultPlan.random(5, uids, 0.4)
        assert [dataclasses.astuple(s) for s in a.specs] == \
               [dataclasses.astuple(s) for s in b.specs]
        assert 0 < len(a.specs) < len(uids)


class TestPolicies:
    def test_retry_schedule(self):
        rp = R.RetryPolicy(max_retries=4, base_delay_s=0.1, multiplier=2.0,
                           max_delay_s=0.5)
        assert rp.schedule() == pytest.approx([0.1, 0.2, 0.4, 0.5])
        assert R.RetryPolicy(base_delay_s=0.0).schedule() == [0.0, 0.0]

    def test_ladder_order_and_threshold(self):
        lad = R.DegradationLadder(["pipeline_serial", "kv_wide", "mask_ref"],
                                  fail_threshold=2)
        rungs = [lad.note_failure() for _ in range(7)]
        # canonical order regardless of construction order, every 2 failures
        assert rungs == [None, "kv_wide", None, "mask_ref", None,
                         "pipeline_serial", None]
        st = lad.state()
        assert st["applied"] == ["kv_wide", "mask_ref", "pipeline_serial"]
        assert st["pending"] == [] and st["failures"] == 7

    def test_ladder_for_serving_filters(self):
        lad = R.DegradationLadder.for_serving(
            kv_quant=None, attn_mask=None, pipeline_depth=0)
        assert lad.pending == []
        spec = AttnMaskSpec(local=True, impl="sparse")
        lad = R.DegradationLadder.for_serving(
            kv_quant="int8", attn_mask=spec, pipeline_depth=1)
        assert lad.pending == ["kv_wide", "mask_ref", "pipeline_serial"]
        lad = R.DegradationLadder.for_serving(
            kv_quant=None, attn_mask=dataclasses.replace(spec, impl="ref"),
            pipeline_depth=1)
        assert lad.pending == ["pipeline_serial"]

    def test_percentiles_empty_and_dirty(self):
        z = _percentiles_ms([])
        assert z == {"p50": 0.0, "p99": 0.0, "mean": 0.0, "n": 0}
        assert _percentiles_ms([None, float("nan"), float("inf")])["n"] == 0
        d = _percentiles_ms([0.001, None, 0.003, float("nan")])
        assert d["n"] == 2 and d["p50"] == pytest.approx(2.0)


# ----------------------------------------------------- satellite fixes ----

class TestStreamPipelineAbort:
    def test_failing_wait_releases_all_slots(self, monkeypatch):
        pipe = engine.StreamPipeline(1)
        orig, calls = jax.block_until_ready, []

        def boom(h):
            calls.append(h)
            if len(calls) == 1:
                raise RuntimeError("deferred device error")
            return orig(h)

        pipe.push("a", jnp.zeros(3))
        monkeypatch.setattr(engine.jax, "block_until_ready", boom)
        with pytest.raises(RuntimeError, match="deferred device error"):
            pipe.push("b", jnp.zeros(3))   # waits "a" out -> raises
        assert len(pipe) == 0              # nothing leaked, nothing wedged
        monkeypatch.setattr(engine.jax, "block_until_ready", orig)
        pipe.push("c", jnp.zeros(3))       # still usable
        pipe.drain()
        assert len(pipe) == 0

    def test_failing_drain_empties(self, monkeypatch):
        pipe = engine.StreamPipeline(1)
        pipe.push("a", jnp.zeros(2))
        monkeypatch.setattr(
            engine.jax, "block_until_ready",
            lambda h: (_ for _ in ()).throw(RuntimeError("boom")))
        with pytest.raises(RuntimeError):
            pipe.drain()
        assert len(pipe) == 0


class TestQuantizeNonFinite:
    def test_raises_by_default(self):
        x = jnp.array([[1.0, jnp.nan], [2.0, 3.0]])
        with pytest.raises(FloatingPointError, match="quantize_rows"):
            precision.quantize_rows(x, "int8")
        with pytest.raises(FloatingPointError, match="quantize_blocks"):
            precision.quantize_blocks(x[None], "fp8_e4m3")
        with pytest.raises(FloatingPointError, match="quantize_tensor"):
            precision.quantize_tensor(jnp.array([jnp.inf, 1.0]), "int8")

    def test_saturate_clamps_deterministically(self):
        x = jnp.array([[jnp.nan, jnp.inf, -jnp.inf, 2.0]])
        q, s = precision.quantize_rows(x, "int8", saturate=True)
        assert np.isfinite(np.asarray(s)).all()
        deq = np.asarray(precision.dequantize_rows(q, s))
        assert np.isfinite(deq).all()       # 3e38 clamp leaves rounding room
        assert deq[0, 0] == 0.0             # NaN -> 0
        q2, s2 = precision.quantize_rows(x, "int8", saturate=True)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(s2))

    def test_noop_under_jit(self):
        # traced values cannot be checked: the guard must not sync or raise
        # at trace time.  The resulting stream is silently corrupt (that is
        # exactly why serving carries a runtime health layer) -- all this
        # test pins down is that jit compilation and execution succeed.
        f = jax.jit(lambda v: precision.quantize_rows(v, "int8"))
        q, s = f(jnp.array([[1.0, jnp.nan]]))
        assert np.asarray(q).shape == (1, 2)
        assert np.asarray(s).shape == (1,)

    def test_finite_path_unchanged(self):
        x = jnp.linspace(-3, 3, 12).reshape(3, 4)
        a = precision.quantize_rows(x, "int8")
        b = precision.quantize_rows(x, "int8", saturate=True)
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_routed_stream_rejects_corrupt_slots():
    with pytest.raises(ValueError, match="flat_slot out of range"):
        moe._build_routed_stream(np.array([[-2, 0, 1]]), 4, 2, 2, 2, 2,
                                 np.float32)


def test_blank_cache_row_resets_quant_row():
    cache = M.init_cache(TINY, 4, MAX_SEQ, dtype=jnp.float32,
                         kv_quant="int8")
    poisoned = R.corrupt_quant_scales(cache, [2], "nan")
    leaves = jax.tree_util.tree_leaves_with_path(poisoned)
    assert any(np.isnan(np.asarray(a)[:, 2]).any() for p, a in leaves
               if "scale" in str(p))
    blanked = M.blank_cache_row(poisoned, 2)

    def check(path, a):
        a = np.asarray(a)
        want = 1.0 if "scale" in str(path) else 0.0
        np.testing.assert_array_equal(a[:, 2], np.full_like(a[:, 2], want))

    jax.tree_util.tree_map_with_path(check, blanked)


def test_dequantize_cache_round_trip():
    cache = M.init_cache(TINY, 2, MAX_SEQ, dtype=jnp.float32,
                         kv_quant="int8")
    wide = R.dequantize_cache(cache, jnp.float32)
    paths = [str(p) for p, _ in jax.tree_util.tree_leaves_with_path(wide)]
    assert not any("scale" in p for p in paths)
    # all-zero cache dequantizes to exact zeros (the scale-1.0 convention)
    for p, a in jax.tree_util.tree_leaves_with_path(wide):
        assert (np.asarray(a) == 0).all()


# ------------------------------------------------------------ fault matrix --

# (stage, kind, selector-kwargs, needs_kv_quant). uid 0 is resident from
# step 0; full stage x kind coverage runs on the bcsr/depth-1 flagship,
# cross-checks on the other backend/depth combos keep tier-1 runtime sane.
MATRIX = [
    ("prefill", "nan", dict(uid=1), None),
    ("prefill", "inf", dict(uid=0), None),
    ("prefill", "exception", dict(uid=1), None),
    ("attention", "inf", dict(uid=0, step=1), None),
    ("route", "nan", dict(uid=0, step=1), None),
    ("route", "exception", dict(step=2), None),
    ("route", "straggler", dict(step=1, delay_s=0.0), None),
    ("execute", "nan", dict(uid=1, step=1), None),
    ("execute", "exception", dict(step=0), None),
    ("sample", "nan", dict(uid=0, step=2), None),
    ("sample", "inf", dict(uid=1, step=0), None),
    ("quantize", "nan", dict(uid=0, step=1), "int8"),
    ("quantize", "inf", dict(uid=1, step=0), "int8"),
]


@pytest.mark.parametrize("stage,kind,sel,kvq",
                         MATRIX, ids=[f"{s}-{k}" for s, k, _, _ in MATRIX])
def test_fault_matrix_bcsr_depth1(params, prompts, baselines,
                                  stage, kind, sel, kvq):
    """Flagship combo: every stage x kind keeps survivors bit-identical."""
    plan = R.FaultPlan.single(stage, kind, **sel)
    sched, out = _run_sched(params, prompts, dispatch="bcsr", depth=1,
                            plan=plan, kv_quant=kvq)
    assert plan.triggered, "fault never fired -- dead test"
    failed = {r.uid for r in sched.failed}
    if kind in ("exception", "straggler") or stage == "prefill":
        # host failures retry from untouched state; stragglers just stall:
        # nobody fails, every request finishes with baseline tokens
        assert not failed
    else:
        assert failed, "activation poison must fail its request"
    _assert_survivors_identical(out, baselines[("bcsr", 1, kvq)],
                                failed_uids=failed)
    # the poisoned/retried paths surface in the health summary
    h = sched.summary()["health"]
    assert h["faults_triggered"] == plan.triggered


CROSS = [
    ("bcsr", 0, "execute", "inf", dict(uid=0, step=1), None),
    ("bcsr", 0, "route", "exception", dict(step=1), None),
    ("bcsr", 0, "quantize", "nan", dict(uid=0, step=0), "int8"),
    ("gather", 1, "sample", "nan", dict(uid=1, step=2), None),
    ("gather", 1, "prefill", "nan", dict(uid=0), None),
    ("gather", 0, "sample", "inf", dict(uid=0, step=1), None),
    ("gather", 0, "quantize", "inf", dict(uid=1, step=1), "int8"),
]


@pytest.mark.parametrize(
    "dispatch,depth,stage,kind,sel,kvq", CROSS,
    ids=[f"{d}-d{p}-{s}-{k}" for d, p, s, k, _, _ in CROSS])
def test_fault_matrix_cross(params, prompts, baselines, dispatch, depth,
                            stage, kind, sel, kvq):
    """The other backend/depth combos hold the same isolation contract."""
    plan = R.FaultPlan.single(stage, kind, **sel)
    sched, out = _run_sched(params, prompts, dispatch=dispatch, depth=depth,
                            plan=plan, kv_quant=kvq)
    assert plan.triggered
    failed = {r.uid for r in sched.failed}
    if kind == "exception" or stage == "prefill":
        assert not failed
    else:
        assert failed
    _assert_survivors_identical(out, baselines[(dispatch, depth, kvq)],
                                failed_uids=failed)


def test_loop_poison_isolated_per_row(params):
    """ServeLoop: a poisoned batch row is flagged in health_rows while the
    other row's tokens stay bit-identical (per-row independence)."""
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, PROMPT), 0,
                                 TINY.vocab_size)
    loop = ServeLoop(params, TINY, max_seq=MAX_SEQ, dispatch="bcsr",
                     two_phase=True, pipeline_depth=1)
    base = loop.run(prompts, GEN)
    assert loop.health_rows.all()
    plan = R.FaultPlan.single("execute", "nan", row=1, step=2)
    fl = ServeLoop(params, TINY, max_seq=MAX_SEQ, dispatch="bcsr",
                   two_phase=True, pipeline_depth=1, fault_plan=plan)
    out = fl.run(prompts, GEN)
    assert list(fl.health_rows) == [True, False]
    np.testing.assert_array_equal(out[0], base[0])
    assert fl.summary()["health"]["rows_finite"] == [True, False]


def test_loop_exception_aborts_pipeline_and_stays_usable(params):
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, PROMPT), 0,
                                 TINY.vocab_size)
    base = ServeLoop(params, TINY, max_seq=MAX_SEQ, dispatch="bcsr",
                     two_phase=True, pipeline_depth=1).run(prompts, GEN)
    plan = R.FaultPlan.single("route", "exception", step=1)
    loop = ServeLoop(params, TINY, max_seq=MAX_SEQ, dispatch="bcsr",
                     two_phase=True, pipeline_depth=1, fault_plan=plan)
    with pytest.raises(R.InjectedFault):
        loop.run(prompts, GEN)
    assert len(loop._pipe) == 0      # no leaked in-flight execute
    out = loop.run(prompts, GEN)     # plan spent: clean rerun, same loop
    np.testing.assert_array_equal(out, base)


# --------------------------------------------------- retry / deadlines ----

class TestRetryPolicyIntegration:
    def test_prefill_retry_to_success(self, params, prompts, baselines):
        plan = R.FaultPlan.single("prefill", "nan", uid=0)
        sched, out = _run_sched(params, prompts, plan=plan)
        assert not sched.failed
        req0 = next(r for r in sched.finished if r.uid == 0)
        assert req0.retries == 1
        _assert_survivors_identical(out, baselines[("bcsr", 1, None)])

    def test_prefill_retry_exhaustion(self, params, prompts, baselines):
        plan = R.FaultPlan.single("prefill", "nan", uid=0, times=99)
        retry = R.RetryPolicy(max_retries=2)
        sched, out = _run_sched(params, prompts, plan=plan, retry=retry)
        failed = {r.uid for r in sched.failed}
        assert failed == {0}
        req0 = sched.failed[0]
        assert req0.state == "failed" and req0.retries == 2
        assert req0.fail_reason == "prefill_poisoned"
        assert req0.slot is None         # slot freed for the next admit
        _assert_survivors_identical(out, baselines[("bcsr", 1, None)],
                                    failed_uids=failed)

    def test_backoff_delays_follow_schedule(self, params, prompts):
        plan = R.FaultPlan.single("prefill", "nan", uid=0, times=99)
        retry = R.RetryPolicy(max_retries=3, base_delay_s=0.01,
                              multiplier=2.0, max_delay_s=0.03)
        sched = ServeScheduler(
            params, TINY, max_seq=MAX_SEQ, max_slots=SLOTS, dispatch="bcsr",
            two_phase=True, cache_dtype=jnp.float32, pipeline_depth=1,
            fault_plan=plan, retry=retry)
        slept = []
        sched._sleep = slept.append
        for p in prompts:
            sched.submit(p, GEN)
        sched.run()
        assert slept == pytest.approx([0.01, 0.02, 0.03])

    def test_decode_retry_exhaustion_raises(self, params, prompts):
        plan = R.FaultPlan.single("route", "exception", step=1, times=99)
        retry = R.RetryPolicy(max_retries=1)
        sched = ServeScheduler(
            params, TINY, max_seq=MAX_SEQ, max_slots=SLOTS, dispatch="bcsr",
            two_phase=True, cache_dtype=jnp.float32, pipeline_depth=1,
            fault_plan=plan, retry=retry)
        for p in prompts:
            sched.submit(p, GEN)
        with pytest.raises(RuntimeError, match="failed after 1 retries"):
            sched.run()
        assert len(sched._pipe) == 0     # aborted clean, not wedged


class TestDeadlinesAndShedding:
    def _sched(self, params, **kw):
        return ServeScheduler(params, TINY, max_seq=MAX_SEQ, max_slots=1,
                              dispatch="gather", two_phase=False,
                              cache_dtype=jnp.float32, **kw)

    def test_deadlines_fake_clock(self, params, prompts):
        t = [0.0]
        sched = self._sched(params, clock=lambda: t[0])
        sched.submit(prompts[0], GEN)
        r1 = sched.submit(prompts[1], GEN, ttft_deadline_s=0.5)
        r2 = sched.submit(prompts[2], GEN, deadline_s=0.3)
        t[0] = 1.0
        sched.step()
        assert {r.uid for r in sched.shed} == {r1.uid, r2.uid}
        assert r1.fail_reason == "ttft_deadline"
        assert r2.fail_reason == "deadline"
        sched.run()
        assert len(sched.finished) == 1
        s = sched.summary()
        assert s["requests"]["shed"] == 2
        assert {e["reason"] for e in s["health"]["shed"]} == \
               {"ttft_deadline", "deadline"}

    def test_resident_total_deadline_fails(self, params, prompts):
        t = [0.0]
        sched = self._sched(params, clock=lambda: t[0])
        req = sched.submit(prompts[0], MAX_SEQ - PROMPT, deadline_s=0.5)
        sched.step()                     # admitted, decoding
        assert req.state == "active"
        t[0] = 1.0
        sched.step()
        assert req.state == "failed" and req.fail_reason == "deadline"
        assert not sched.has_work()

    def test_bounded_queue_reject(self, params, prompts):
        sched = self._sched(params, max_queue=1, shed_policy="reject")
        sched.submit(prompts[0], 2)
        with pytest.raises(R.ShedError, match="queue full"):
            sched.submit(prompts[1], 2)
        assert sched.health.counters["shed"] == 1

    def test_bounded_queue_drop_oldest(self, params, prompts):
        sched = self._sched(params, max_queue=1, shed_policy="drop_oldest")
        a = sched.submit(prompts[0], 2)
        b = sched.submit(prompts[1], 2)
        assert a.state == "shed" and a.fail_reason == "queue_full_drop_oldest"
        assert list(sched.queue) == [b]

    def test_empty_run_summary_zeroes(self, params, prompts):
        # every request shed before first token: percentiles must be zeros
        t = [0.0]
        sched = self._sched(params, clock=lambda: t[0])
        sched.submit(prompts[0], GEN, deadline_s=0.1)
        t[0] = 1.0
        sched.step()
        s = sched.summary()
        assert s["token_latency_ms"]["n"] == 0
        assert s["first_token_ms"] == {"p50": 0.0, "p99": 0.0, "mean": 0.0,
                                       "n": 0}


# ------------------------------------------------------------- ladder -----

def test_ladder_integration_walks_rungs(params, prompts, baselines):
    """fail_threshold=1: each failure applies the next applicable rung --
    kv_wide flips the live cache to scale-free wide f32, pipeline_serial
    drops to depth 0 -- and the scheduler keeps serving afterwards."""
    plan = R.FaultPlan([
        R.FaultSpec("execute", "nan", uid=0, step=0),
        R.FaultSpec("execute", "nan", uid=1, step=1),
    ])
    sched, out = _run_sched(params, prompts, depth=1, kv_quant="int8",
                            plan=plan, fail_threshold=1)
    st = sched.ladder.state()
    assert st["applied"] == ["kv_wide", "pipeline_serial"]
    assert sched.kv_quant is None and sched.pipeline_depth == 0
    assert sched._pipe.depth == 0
    paths = [str(p) for p, _ in
             jax.tree_util.tree_leaves_with_path(sched.cache)]
    assert not any("scale" in p for p in paths)
    assert len(sched.finished) == 1      # the non-faulted request completed
    degr = [e for e in sched.summary()["health"]["events"]
            if e["event"] == "degrade"]
    assert [e["rung"] for e in degr] == ["kv_wide", "pipeline_serial"]


def test_mask_ref_rung_rewrites_spec(params):
    spec = AttnMaskSpec(local=True, impl="sparse")
    loop = ServeLoop(params, TINY, max_seq=MAX_SEQ, dispatch="gather",
                     two_phase=False, attn_mask=spec)
    assert "mask_ref" in loop.ladder.pending
    loop._apply_rung("mask_ref")
    assert loop.attn_mask.impl == "ref"
    assert loop.attn_mask.local == spec.local   # only impl changes


# ------------------------------------------------------- sync accounting --

def test_depth1_health_adds_no_syncs(params, prompts, baselines,
                                     monkeypatch):
    """The healthy pipelined path performs exactly ONE device fetch per
    decode step (the token ids) -- the isfinite health bits ride inside
    it, not beside it."""
    sched = ServeScheduler(
        params, TINY, max_seq=MAX_SEQ, max_slots=SLOTS, dispatch="bcsr",
        two_phase=True, cache_dtype=jnp.float32, pipeline_depth=1)
    for p in prompts:
        sched.submit(p, GEN)
    fetches = []
    orig = jax.device_get
    monkeypatch.setattr(jax, "device_get", lambda x: fetches.append(1)
                        or orig(x))
    out = sched.run()
    decode_steps = sum(1 for s in sched.stats if s.phase == "decode")
    assert len(fetches) == decode_steps
    _assert_survivors_identical(out, baselines[("bcsr", 1, None)])


# ------------------------------------------------------------- stress -----

@pytest.mark.stress
def test_randomized_fault_stress(params):
    """Seeded random trace x random fault plan: staggered joins, random
    faults across stages/kinds, and every survivor still bit-identical to
    the fault-free run of the same trace."""
    rng = np.random.default_rng(7)
    n_req = 10
    prompts = [rng.integers(0, TINY.vocab_size, int(rng.integers(4, PROMPT)))
               for _ in range(n_req)]
    gens = [int(rng.integers(2, GEN + 1)) for _ in range(n_req)]

    def drive(plan):
        sched = ServeScheduler(
            params, TINY, max_seq=MAX_SEQ, max_slots=4, dispatch="bcsr",
            two_phase=True, cache_dtype=jnp.float32, pipeline_depth=1,
            fault_plan=plan)
        pending = list(zip(prompts, gens))
        i = 0
        while pending or sched.has_work():
            # staggered arrivals: up to 2 submissions per tick
            for _ in range(min(2, len(pending))):
                p, g = pending.pop(0)
                sched.submit(p, g)
            if sched.has_work():
                sched.step()
            i += 1
            assert i < 500, "scheduler wedged"
        return sched, {r.uid: np.asarray(r.tokens, np.int32)
                       for r in sched.finished}

    _, base = drive(None)
    assert len(base) == n_req
    plan = R.FaultPlan.random(11, list(range(n_req)), 0.5)
    assert plan.specs, "seed produced no faults -- pick another"
    sched, out = drive(plan)
    failed = {r.uid for r in sched.failed}
    assert plan.triggered
    _assert_survivors_identical(out, base, failed_uids=failed)
    # terminal states partition the request set
    assert failed | set(out) == set(range(n_req))
