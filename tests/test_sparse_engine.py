"""Sharded + batched sparse engine vs. the single-device kernels.

Runs on a CPU mesh of virtual devices (conftest.py forces
``--xla_force_host_platform_device_count=4``).  The engine's contract is
*bit-for-bit* fp32 parity with the single-device kernel: every device runs
the identical Pallas program on identical operand values for its output
tiles, so not even accumulation order changes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.formats import (batched_bcsr_from_dense, bcsr_from_dense,
                                powerlaw_sparse, random_dense_sparse)
from repro.kernels import engine
from repro.kernels.spmm import ops as spmm_ops
from repro.kernels.spmm.ref import spmm_ref
from repro.kernels.spmspm import ops as spmspm_ops
from repro.kernels.spmspm.ref import spmspm_ref

RNG = np.random.default_rng(42)

pytestmark = pytest.mark.skipif(
    jax.device_count() < 2, reason="needs a >=2-device mesh "
    "(set XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def _mesh(n):
    return jax.make_mesh((n,), ("data",))


def test_mesh_has_virtual_devices():
    assert jax.device_count() >= 2


def test_ensure_virtual_devices_detects_late_call():
    """Once the backend is initialized the XLA_FLAGS override is inert:
    asking for more devices than exist must warn (raise under strict),
    not silently leave sharded tests on one device.  Asking for what we
    already have stays silent."""
    import warnings

    assert jax.local_device_count() >= 2  # backend is up (conftest: 4)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        engine.ensure_virtual_devices(jax.local_device_count())
    with pytest.warns(RuntimeWarning, match="already initialized"):
        engine.ensure_virtual_devices(jax.local_device_count() + 64)
    with pytest.raises(RuntimeError, match="already initialized"):
        engine.ensure_virtual_devices(jax.local_device_count() + 64,
                                      strict=True)


# ---------------------------------------------------------------------------
# SpMM: N-partitioned
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_dev", [2, 4])
@pytest.mark.parametrize("N", [512, 256])
def test_shard_spmm_bitwise_matches_single_device(n_dev, N):
    a_dense = random_dense_sparse(RNG, (64, 64), 0.3)
    a = bcsr_from_dense(a_dense, (8, 8))
    b = jnp.asarray(RNG.standard_normal((64, N)), jnp.float32)
    got = engine.shard_spmm(a, b, mesh=_mesh(n_dev))
    want = spmm_ops.spmm(a, b, bn=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("N", [100, 300, 129])
def test_shard_spmm_uneven_n_tiles(N):
    """N not divisible by n_dev * bn: the engine pads and strips."""
    a_dense = random_dense_sparse(RNG, (32, 64), 0.4)
    a = bcsr_from_dense(a_dense, (8, 8))
    b = jnp.asarray(RNG.standard_normal((64, N)), jnp.float32)
    got = engine.shard_spmm(a, b, mesh=_mesh(4))
    assert got.shape == (32, N)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(spmm_ops.spmm(a, b, interpret=True)))


def test_shard_spmm_matches_oracle_powerlaw():
    """Sharded path against the densify-and-matmul oracle (not just the
    kernel), on a row-imbalanced matrix."""
    a_dense = powerlaw_sparse(RNG, (64, 64), 0.1)
    a = bcsr_from_dense(a_dense, (8, 8))
    b = jnp.asarray(RNG.standard_normal((64, 200)), jnp.float32)
    got = engine.shard_spmm(a, b, mesh=_mesh(2))
    np.testing.assert_allclose(np.asarray(got), np.asarray(spmm_ref(a, b)),
                               atol=1e-4, rtol=1e-4)


def test_shard_spmm_auto_mesh():
    """mesh=None resolves to a 1-D mesh over all local devices."""
    a = bcsr_from_dense(random_dense_sparse(RNG, (32, 32), 0.5), (8, 8))
    b = jnp.asarray(RNG.standard_normal((32, 256)), jnp.float32)
    got = engine.shard_spmm(a, b)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(spmm_ops.spmm(a, b, interpret=True)))


# ---------------------------------------------------------------------------
# Batched SpMM: batch-partitioned
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B", [4, 3, 6])  # 3 exercises the uneven-batch pad
def test_shard_spmm_batched_matches_per_matrix(B):
    stack = np.stack(
        [random_dense_sparse(RNG, (64, 64), 0.2) for _ in range(B)])
    a = batched_bcsr_from_dense(stack, (8, 8))
    d = jnp.asarray(RNG.standard_normal((B, 64, 160)), jnp.float32)
    got = engine.shard_spmm_batched(a, d, mesh=_mesh(4))
    assert got.shape == (B, 64, 160)
    for i in range(B):
        want = spmm_ops.spmm(a[i], d[i], interpret=True)
        np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(want))


def test_shard_spmm_batched_broadcast_dense():
    """(K, N) dense broadcasts across the batch (MoE dispatch shape)."""
    stack = np.stack(
        [random_dense_sparse(RNG, (32, 32), 0.3) for _ in range(4)])
    a = batched_bcsr_from_dense(stack, (8, 8))
    d = jnp.asarray(RNG.standard_normal((32, 128)), jnp.float32)
    got = engine.shard_spmm_batched(a, d, mesh=_mesh(2))
    want = spmm_ops.spmm_batched(a, d, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Bucketed streams (two-phase serving support)
# ---------------------------------------------------------------------------

def test_stream_bucket_law():
    """Power-of-two snap with a floor: the compile-cache-bounding law."""
    assert engine.stream_bucket(1) == 8          # default floor
    assert engine.stream_bucket(8) == 8
    assert engine.stream_bucket(9) == 16
    assert engine.stream_bucket(100) == 128
    assert engine.stream_bucket(128) == 128
    assert engine.stream_bucket(3, minimum=32) == 32
    for n in range(1, 200):
        b = engine.stream_bucket(n)
        assert b >= n and b <= 2 * max(n, 8) and (b & (b - 1)) == 0


def test_with_capacity_pads_zero_blocks_bitwise():
    """nnzb-padded container: same todense, same product, sorted stream,
    row coverage preserved."""
    stack = np.stack(
        [random_dense_sparse(RNG, (32, 64), 0.15) for _ in range(3)])
    a = batched_bcsr_from_dense(stack, (8, 8))
    cap = engine.stream_bucket(a.nnzb)
    ap = a.with_capacity(cap)
    assert ap.nnzb == cap and a.nnzb <= cap
    np.testing.assert_array_equal(np.asarray(ap.todense()),
                                  np.asarray(a.todense()))
    rows = np.asarray(ap.block_rows)
    cols = np.asarray(ap.block_cols)
    assert (np.lexsort((cols, rows)) == np.arange(cap)).all(), "stream sorted"
    with pytest.raises(ValueError, match="can only grow"):
        ap.with_capacity(ap.nnzb - 1)
    assert a.with_capacity(a.nnzb) is a  # no-op fast path


def test_shard_spmm_batched_bucketed_matches_unbucketed():
    """Bucket padding is invisible in the product (zero blocks), and the
    stream length is the bucket."""
    stack = np.stack(
        [random_dense_sparse(RNG, (64, 64), 0.1) for _ in range(4)])
    a = batched_bcsr_from_dense(stack, (8, 8))
    d = jnp.asarray(RNG.standard_normal((4, 64, 160)), jnp.float32)
    got = engine.shard_spmm_batched_bucketed(a, d, mesh=_mesh(4))
    want = engine.shard_spmm_batched(a, d, mesh=_mesh(4))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_shard_spmm_batched_stream_is_trace_safe():
    """The stream entry point runs under jit with the index arrays as
    traced arguments (the phase-2 contract)."""
    stack = np.stack(
        [random_dense_sparse(RNG, (32, 32), 0.3) for _ in range(2)])
    a = spmm_ops.pad_empty_rows(batched_bcsr_from_dense(stack, (8, 8)))
    d = jnp.asarray(RNG.standard_normal((2, 32, 128)), jnp.float32)

    fn = jax.jit(lambda a, d: engine.shard_spmm_batched_stream(
        a, d, mesh=_mesh(2)))
    got = fn(a, d)
    want = engine.shard_spmm_batched(a, d, mesh=_mesh(2))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mesh_interning_dedups_equal_meshes():
    """Equal-but-fresh Mesh objects resolve to ONE interned mesh, so the
    lru-cached sharded programs never recompile for a recreated mesh."""
    m1, _ = engine.auto_mesh(jax.make_mesh((2,), ("data",)))
    m2, _ = engine.auto_mesh(jax.make_mesh((2,), ("data",)))
    assert m1 is m2
    m3, _ = engine.auto_mesh(jax.make_mesh((2,), ("model",)))
    assert m3 is not m1  # different axis names = different program

    a = bcsr_from_dense(random_dense_sparse(RNG, (32, 32), 0.5), (8, 8))
    b = jnp.asarray(RNG.standard_normal((32, 256)), jnp.float32)
    engine.shard_spmm(a, b, mesh=jax.make_mesh((2,), ("data",)))
    n_cached = engine._sharded_spmm_fn.cache_info().currsize
    engine.shard_spmm(a, b, mesh=jax.make_mesh((2,), ("data",)))
    assert engine._sharded_spmm_fn.cache_info().currsize == n_cached


def test_backend_initialized_probe():
    """The version-tolerant probe reports True here (conftest initialized
    the backend long ago) and never raises."""
    assert engine.backend_initialized() in (True, None)


# ---------------------------------------------------------------------------
# SpMSpM: output-column-partitioned
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_dev", [2, 4])
def test_shard_spmspm_bitwise_matches_single_device(n_dev):
    A = random_dense_sparse(RNG, (24, 96), 0.3)
    B = random_dense_sparse(RNG, (96, 32), 0.1)
    ak, av = spmspm_ops.dense_to_ell_rows(A)
    bk, bv = spmspm_ops.dense_to_ell_cols(B)
    got = engine.shard_spmspm(ak, av, bk, bv, mesh=_mesh(n_dev),
                              rt=8, ct=8)
    want = spmspm_ops.spmspm(ak, av, bk, bv, rt=8, ct=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_shard_spmspm_uneven_columns():
    """C not divisible by n_dev * ct: INVALID-key padding, stripped after."""
    A = random_dense_sparse(RNG, (16, 64), 0.4)
    B = random_dense_sparse(RNG, (64, 22), 0.15)
    ak, av = spmspm_ops.dense_to_ell_rows(A)
    bk, bv = spmspm_ops.dense_to_ell_cols(B)
    got = engine.shard_spmspm(ak, av, bk, bv, mesh=_mesh(4))
    assert got.shape == (16, 22)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(spmspm_ref(ak, av, bk, bv, 64)),
        atol=1e-4, rtol=1e-4)


def test_shard_spmspm_empty_operand():
    """An all-zero B produces an all-zero product (pure INVALID streams)."""
    A = random_dense_sparse(RNG, (16, 64), 0.4)
    B = np.zeros((64, 16), np.float32)
    ak, av = spmspm_ops.dense_to_ell_rows(A)
    bk, bv = spmspm_ops.dense_to_ell_cols(B)
    got = engine.shard_spmspm(ak, av, bk, bv, mesh=_mesh(2))
    np.testing.assert_array_equal(np.asarray(got), np.zeros((16, 16)))
