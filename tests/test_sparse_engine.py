"""Sharded + batched sparse engine vs. the single-device kernels.

Runs on a CPU mesh of virtual devices (conftest.py forces
``--xla_force_host_platform_device_count=4``).  The engine's contract is
*bit-for-bit* fp32 parity with the single-device kernel: every device runs
the identical Pallas program on identical operand values for its output
tiles, so not even accumulation order changes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.formats import (batched_bcsr_from_dense, bcsr_from_dense,
                                powerlaw_sparse, random_dense_sparse)
from repro.kernels import engine
from repro.kernels.spmm import ops as spmm_ops
from repro.kernels.spmm.ref import spmm_ref
from repro.kernels.spmspm import ops as spmspm_ops
from repro.kernels.spmspm.ref import spmspm_ref

RNG = np.random.default_rng(42)

pytestmark = pytest.mark.skipif(
    jax.device_count() < 2, reason="needs a >=2-device mesh "
    "(set XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def _mesh(n):
    return jax.make_mesh((n,), ("data",))


def test_mesh_has_virtual_devices():
    assert jax.device_count() >= 2


def test_ensure_virtual_devices_detects_late_call():
    """Once the backend is initialized the XLA_FLAGS override is inert:
    asking for more devices than exist must warn (raise under strict),
    not silently leave sharded tests on one device.  Asking for what we
    already have stays silent."""
    import warnings

    assert jax.local_device_count() >= 2  # backend is up (conftest: 4)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        engine.ensure_virtual_devices(jax.local_device_count())
    with pytest.warns(RuntimeWarning, match="already initialized"):
        engine.ensure_virtual_devices(jax.local_device_count() + 64)
    with pytest.raises(RuntimeError, match="already initialized"):
        engine.ensure_virtual_devices(jax.local_device_count() + 64,
                                      strict=True)


# ---------------------------------------------------------------------------
# SpMM: N-partitioned
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_dev", [2, 4])
@pytest.mark.parametrize("N", [512, 256])
def test_shard_spmm_bitwise_matches_single_device(n_dev, N):
    a_dense = random_dense_sparse(RNG, (64, 64), 0.3)
    a = bcsr_from_dense(a_dense, (8, 8))
    b = jnp.asarray(RNG.standard_normal((64, N)), jnp.float32)
    got = engine.shard_spmm(a, b, mesh=_mesh(n_dev))
    want = spmm_ops.spmm(a, b, bn=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("N", [100, 300, 129])
def test_shard_spmm_uneven_n_tiles(N):
    """N not divisible by n_dev * bn: the engine pads and strips."""
    a_dense = random_dense_sparse(RNG, (32, 64), 0.4)
    a = bcsr_from_dense(a_dense, (8, 8))
    b = jnp.asarray(RNG.standard_normal((64, N)), jnp.float32)
    got = engine.shard_spmm(a, b, mesh=_mesh(4))
    assert got.shape == (32, N)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(spmm_ops.spmm(a, b, interpret=True)))


def test_shard_spmm_matches_oracle_powerlaw():
    """Sharded path against the densify-and-matmul oracle (not just the
    kernel), on a row-imbalanced matrix."""
    a_dense = powerlaw_sparse(RNG, (64, 64), 0.1)
    a = bcsr_from_dense(a_dense, (8, 8))
    b = jnp.asarray(RNG.standard_normal((64, 200)), jnp.float32)
    got = engine.shard_spmm(a, b, mesh=_mesh(2))
    np.testing.assert_allclose(np.asarray(got), np.asarray(spmm_ref(a, b)),
                               atol=1e-4, rtol=1e-4)


def test_shard_spmm_auto_mesh():
    """mesh=None resolves to a 1-D mesh over all local devices."""
    a = bcsr_from_dense(random_dense_sparse(RNG, (32, 32), 0.5), (8, 8))
    b = jnp.asarray(RNG.standard_normal((32, 256)), jnp.float32)
    got = engine.shard_spmm(a, b)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(spmm_ops.spmm(a, b, interpret=True)))


# ---------------------------------------------------------------------------
# Batched SpMM: batch-partitioned
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B", [4, 3, 6])  # 3 exercises the uneven-batch pad
def test_shard_spmm_batched_matches_per_matrix(B):
    stack = np.stack(
        [random_dense_sparse(RNG, (64, 64), 0.2) for _ in range(B)])
    a = batched_bcsr_from_dense(stack, (8, 8))
    d = jnp.asarray(RNG.standard_normal((B, 64, 160)), jnp.float32)
    got = engine.shard_spmm_batched(a, d, mesh=_mesh(4))
    assert got.shape == (B, 64, 160)
    for i in range(B):
        want = spmm_ops.spmm(a[i], d[i], interpret=True)
        np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(want))


def test_shard_spmm_batched_broadcast_dense():
    """(K, N) dense broadcasts across the batch (MoE dispatch shape)."""
    stack = np.stack(
        [random_dense_sparse(RNG, (32, 32), 0.3) for _ in range(4)])
    a = batched_bcsr_from_dense(stack, (8, 8))
    d = jnp.asarray(RNG.standard_normal((32, 128)), jnp.float32)
    got = engine.shard_spmm_batched(a, d, mesh=_mesh(2))
    want = spmm_ops.spmm_batched(a, d, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# SpMSpM: output-column-partitioned
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_dev", [2, 4])
def test_shard_spmspm_bitwise_matches_single_device(n_dev):
    A = random_dense_sparse(RNG, (24, 96), 0.3)
    B = random_dense_sparse(RNG, (96, 32), 0.1)
    ak, av = spmspm_ops.dense_to_ell_rows(A)
    bk, bv = spmspm_ops.dense_to_ell_cols(B)
    got = engine.shard_spmspm(ak, av, bk, bv, mesh=_mesh(n_dev),
                              rt=8, ct=8)
    want = spmspm_ops.spmspm(ak, av, bk, bv, rt=8, ct=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_shard_spmspm_uneven_columns():
    """C not divisible by n_dev * ct: INVALID-key padding, stripped after."""
    A = random_dense_sparse(RNG, (16, 64), 0.4)
    B = random_dense_sparse(RNG, (64, 22), 0.15)
    ak, av = spmspm_ops.dense_to_ell_rows(A)
    bk, bv = spmspm_ops.dense_to_ell_cols(B)
    got = engine.shard_spmspm(ak, av, bk, bv, mesh=_mesh(4))
    assert got.shape == (16, 22)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(spmspm_ref(ak, av, bk, bv, 64)),
        atol=1e-4, rtol=1e-4)


def test_shard_spmspm_empty_operand():
    """An all-zero B produces an all-zero product (pure INVALID streams)."""
    A = random_dense_sparse(RNG, (16, 64), 0.4)
    B = np.zeros((64, 16), np.float32)
    ak, av = spmspm_ops.dense_to_ell_rows(A)
    bk, bv = spmspm_ops.dense_to_ell_cols(B)
    got = engine.shard_spmspm(ak, av, bk, bv, mesh=_mesh(2))
    np.testing.assert_array_equal(np.asarray(got), np.zeros((16, 16)))
