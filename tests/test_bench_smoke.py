"""Benchmark-harness smoke (the ``bench`` tier, enable with --run-bench).

One tiny sweep point per op in interpret mode, so the ``sweep_tiles``
harness (and its ``tuning.register`` wiring + JSON artifact schema) cannot
bit-rot without CI noticing.  register=False keeps the process-global
tuning table untouched for any tests that follow.
"""
import json
import os
import sys

import pytest

pytestmark = pytest.mark.bench

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def sweep_results():
    sys.path.insert(0, REPO_ROOT)  # benchmarks/ is not a package on sys.path
    try:
        from benchmarks import sweep_tiles
    finally:
        sys.path.pop(0)
    return sweep_tiles.run(smoke=True, register=False)


def test_sweep_smoke_points_are_bit_identical(sweep_results):
    spmm = sweep_results["spmm"]
    assert spmm["points"], "sweep produced no points"
    assert all(p["bit_identical"] for p in spmm["points"])
    assert not spmm["registered"]
    # the residency invariant: more resident tiles, fewer stream walks
    by_nt = {p["nt"]: p["stream_walks"] for p in spmm["points"]
             if p["bn"] == spmm["points"][0]["bn"]}
    if len(by_nt) > 1:
        assert by_nt[max(by_nt)] < by_nt[min(by_nt)]


def test_sweep_smoke_bucket_points(sweep_results):
    moe = sweep_results["moe_dispatch"]
    assert moe["points"] and "min_bucket" in moe["winner"]
    for p in moe["points"]:
        assert p["nnzb_stream"] >= p["nnzb_covered"]


def test_emit_bench_schema(tmp_path, sweep_results):
    from benchmarks.common import emit_bench

    path = emit_bench("smoke_test", sweep_results, directory=str(tmp_path))
    with open(path) as f:
        doc = json.load(f)
    assert doc["bench"] == "smoke_test"
    assert {"backend", "device_count", "jax_version"} <= set(doc)
    assert doc["spmm"]["points"]
