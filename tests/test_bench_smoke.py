"""Benchmark-harness smoke (the ``bench`` tier, enable with --run-bench).

One tiny sweep point per op in interpret mode, so the ``sweep_tiles``
harness (and its ``tuning.register`` wiring + JSON artifact schema) cannot
bit-rot without CI noticing.  register=False keeps the process-global
tuning table untouched for any tests that follow.
"""
import json
import os
import sys

import pytest

pytestmark = pytest.mark.bench

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def sweep_results():
    sys.path.insert(0, REPO_ROOT)  # benchmarks/ is not a package on sys.path
    try:
        from benchmarks import sweep_tiles
    finally:
        sys.path.pop(0)
    return sweep_tiles.run(smoke=True, register=False)


def test_sweep_smoke_points_are_bit_identical(sweep_results):
    spmm = sweep_results["spmm"]
    assert spmm["points"], "sweep produced no points"
    assert all(p["bit_identical"] for p in spmm["points"])
    assert not spmm["registered"]
    # the residency invariant: more resident tiles, fewer stream walks
    by_nt = {p["nt"]: p["stream_walks"] for p in spmm["points"]
             if p["bn"] == spmm["points"][0]["bn"]}
    if len(by_nt) > 1:
        assert by_nt[max(by_nt)] < by_nt[min(by_nt)]


def test_sweep_smoke_bucket_points(sweep_results):
    moe = sweep_results["moe_dispatch"]
    assert moe["points"] and "min_bucket" in moe["winner"]
    for p in moe["points"]:
        assert p["nnzb_stream"] >= p["nnzb_covered"]


def test_sweep_smoke_flash_points(sweep_results):
    """The flash (bq, bk) sweep: every dense and sparse point oracle-parity,
    winner carries both the sparse and dense tile picks, nothing
    registered."""
    fl = sweep_results["flash"]
    assert fl["dense_points"] and fl["sparse_points"]
    assert all(p["parity"] for p in fl["points"])
    assert not fl["registered"]
    assert {"bq", "bk", "dense_bq", "dense_bk"} <= set(fl["winner"])
    S = fl["shape"]["S"]
    for p in fl["sparse_points"]:
        # the window walk is structurally below the dense grid
        assert p["walked_tiles"] < (S // p["bq"]) * (S // p["bk"]) or \
            p["walked_tiles"] <= 8  # tiny smoke grids bottom out at the bucket floor


def test_emit_bench_schema(tmp_path, sweep_results):
    from benchmarks.common import emit_bench

    path = emit_bench("smoke_test", sweep_results, directory=str(tmp_path))
    with open(path) as f:
        doc = json.load(f)
    assert doc["bench"] == "smoke_test"
    assert {"backend", "device_count", "jax_version"} <= set(doc)
    assert doc["spmm"]["points"]


@pytest.fixture(scope="module")
def serve_results():
    sys.path.insert(0, REPO_ROOT)
    try:
        from benchmarks import bench_serve
    finally:
        sys.path.pop(0)
    return bench_serve.run(smoke=True, fault_rate=0.5)


def test_bench_serve_smoke(serve_results):
    """The continuous-batching bench drains its trace on both backends and
    reports sane throughput/latency numbers."""
    for backend in ("gather", "bcsr"):
        e = serve_results[backend]
        t = e["trace"]
        assert e["requests_finished"] == t["requests"]
        assert t["generated_tokens"] > 0
        assert e["decode_tok_per_s"] > 0
        lat = e["token_latency_ms"]
        assert lat["n"] == t["generated_tokens"]
        assert 0 < lat["p50"] <= lat["p99"]
        ftl = e["first_token_ms"]
        assert ftl["n"] == t["requests"] and ftl["p50"] > 0


def test_bench_serve_pipelined_ab(serve_results):
    """The serial-vs-pipelined A/B row (PR 7): the pipelined run drains the
    same trace, emits the *same tokens*, and the hidden-route fraction is a
    valid fraction.  (Speedup itself is not asserted at smoke shapes --
    interpret-mode executes finish before the next route can overlap.)"""
    for backend in ("gather", "bcsr"):
        e = serve_results[backend]
        assert e["pipeline_depth"] == 0        # top level stays the serial run
        pip, ab = e["pipelined"], e["ab"]
        assert pip["pipeline_depth"] == 1
        assert pip["requests_finished"] == pip["trace"]["requests"]
        assert pip["trace"]["generated_tokens"] == e["trace"]["generated_tokens"]
        assert ab["tokens_match"] is True
        assert ab["pipelined_tok_per_s"] > 0 and ab["serial_tok_per_s"] > 0
        assert ab["decode_speedup"] > 0
        assert 0.0 <= ab["route_hidden_frac"] <= 1.0
        if e["two_phase"]:   # gather is fused: no route/execute stats
            assert pip["timing"]["execute_dispatch_ms"] >= 0.0


@pytest.fixture(scope="module")
def attention_results():
    sys.path.insert(0, REPO_ROOT)
    try:
        from benchmarks import bench_attention
    finally:
        sys.path.pop(0)
    return bench_attention.run(smoke=True)


def test_bench_attention_smoke(attention_results):
    """The sparse-vs-dense attention bench: every point bit-identical to the
    dense-masked kernel, walked-tile counts below the dense grid, schema
    stable for the BENCH_attention.json artifact."""
    assert attention_results["points"]
    for p in attention_results["points"]:
        assert p["parity_bit_identical"] is True
        assert p["walked_tiles"] <= p["walked_tiles_bucketed"]
        assert p["walked_tiles_bucketed"] <= p["dense_tiles"]
        assert p["t_dense_us"] > 0 and p["t_sparse_us"] > 0
        assert p["speedup"] > 0
    # windows are increasing fractions -> walked tiles monotone nondecreasing
    walked = [p["walked_tiles"] for p in attention_results["points"]]
    assert walked == sorted(walked)


@pytest.fixture(scope="module")
def precision_results():
    sys.path.insert(0, REPO_ROOT)
    try:
        from benchmarks import bench_precision
    finally:
        sys.path.pop(0)
    return bench_precision.sweep(smoke=True)


def test_bench_precision_smoke(precision_results):
    """The narrow-precision sweep measures all three quantized dtypes and
    the BlockQuant contracts hold at bench shapes too: every quantized spmm
    point is bit-identical to its dequantize-then-f32 reference, and the
    int8 serving row reproduces the f32 loop's greedy tokens exactly."""
    spmm = precision_results["spmm"]
    for name in ("fp8_e4m3", "fp8_e5m2", "int8"):
        p = spmm["points"][name]
        assert p["bit_identical_vs_dequant_ref"] is True
        assert p["time_us"] > 0
        assert p["rel_err"] < 0.1
    assert spmm["points"]["f32"]["max_abs_err"] == 0.0
    serving = precision_results["serving"]
    assert serving["int8"]["tokens_match_frac"] == 1.0
    for name in ("fp8_e4m3", "fp8_e5m2", "int8"):
        assert serving[name]["first_decode_logit_rel_err"] < 0.2


def test_bench_serve_fault_ab(serve_results):
    """The healthy-vs-faulty A/B row (PR 10): the faulty run drains under a
    seeded random fault plan, every request reaches a terminal state, and
    surviving requests emit tokens bit-identical to the healthy pipelined
    run (per-request isolation)."""
    for backend in ("gather", "bcsr"):
        fl = serve_results[backend]["fault"]
        assert fl["fault_rate"] == 0.5
        assert fl["faults_injected"] > 0
        assert fl["survivor_tokens_match"] is True
        n_req = serve_results[backend]["trace"]["requests"]
        assert fl["finished"] + fl["failed"] + fl["shed"] == n_req
        assert fl["faulty_tok_per_s"] > 0


def test_bench_serve_signature_bound(serve_results):
    """The batch-bucket law holds under the synthetic trace: phase-2
    recompiles stay within the (batch-bucket x nnzb-bucket x token-shape)
    budget, and every observed batch bucket is a power of two."""
    e = serve_results["bcsr"]
    assert e["two_phase"]
    assert e["compile_signatures"] <= e["signature_bound"]
    for b in e["batch_buckets"]:
        assert b & (b - 1) == 0 and b > 0
