"""WKV Pallas kernel vs. sequential oracle: shape/chunk/decay sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.wkv import ops
from repro.kernels.wkv.ref import wkv_ref

RNG = np.random.default_rng(5)


def _inputs(B, T, nh, hd, wmag):
    r = jnp.asarray(RNG.standard_normal((B, T, nh, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, T, nh, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, T, nh, hd)), jnp.float32)
    w = jnp.maximum(-jnp.abs(jnp.asarray(
        RNG.standard_normal((B, T, nh, hd)), jnp.float32)) * wmag, -1.0)
    u = jnp.asarray(RNG.standard_normal((nh, hd)), jnp.float32) * 0.1
    return r, k, v, w, u


@pytest.mark.parametrize("T,chunk", [(64, 16), (100, 32), (256, 128)])
@pytest.mark.parametrize("wmag", [0.05, 1.0])  # incl. clamp-saturating decay
def test_wkv_kernel_matches_oracle(T, chunk, wmag):
    r, k, v, w, u = _inputs(2, T, 3, 16, wmag)
    got = ops.wkv(r, k, v, w, u, chunk=chunk, interpret=True)
    want = wkv_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


def test_wkv_kernel_bf16_inputs():
    r, k, v, w, u = _inputs(1, 64, 2, 16, 0.1)
    got = ops.wkv(r.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
                  v.astype(jnp.bfloat16), w, u, chunk=32, interpret=True)
    want = wkv_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-2, rtol=5e-2)


def test_wkv_flops_accounting():
    assert ops.flops(2, 256, 4, 64, chunk=128) > 0
