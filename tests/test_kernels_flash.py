"""Flash attention kernel vs. full-softmax oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops
from repro.kernels.flash_attention.ref import attention_ref

RNG = np.random.default_rng(3)


def _qkv(B, Hq, Hkv, S, D, dtype=jnp.float32):
    q = jnp.asarray(RNG.standard_normal((B, Hq, S, D)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, Hkv, S, D)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, Hkv, S, D)), dtype)
    return q, k, v


@pytest.mark.parametrize("S", [128, 256])
@pytest.mark.parametrize("heads", [(4, 4), (8, 2)])  # MHA and GQA
@pytest.mark.parametrize("causal", [True, False])
def test_flash_basic(S, heads, causal):
    Hq, Hkv = heads
    q, k, v = _qkv(2, Hq, Hkv, S, 64)
    got = ops.attention(q, k, v, causal=causal, bq=128, bk=128, interpret=True)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


def test_flash_sliding_window():
    q, k, v = _qkv(1, 4, 2, 256, 32)
    got = ops.attention(q, k, v, causal=True, window=64, bq=64, bk=64,
                        interpret=True)
    want = attention_ref(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


def test_flash_unaligned_seq():
    q, k, v = _qkv(1, 2, 2, 200, 32)
    got = ops.attention(q, k, v, causal=True, bq=128, bk=128, interpret=True)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


def test_flash_bf16():
    q, k, v = _qkv(1, 4, 4, 128, 64, jnp.bfloat16)
    got = ops.attention(q, k, v, causal=True, interpret=True)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=5e-2, rtol=5e-2)


def test_decode_attention_matches_full():
    B, Hq, Hkv, S, D = 2, 4, 2, 64, 32
    q, k, v = _qkv(B, Hq, Hkv, S, D)
    full = attention_ref(q, k, v, causal=True)
    q_last = q[:, :, -1:, :]
    dec = ops.decode_attention(q_last, k, v, kv_len=np.full((B,), S))
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, :, -1:, :]),
                               atol=2e-3, rtol=2e-3)
