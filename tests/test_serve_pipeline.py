"""Pipelined serving (PR 7): ``pipeline_depth=1`` vs the serial loop.

The contract under test (see "Pipelined serving contract" in
``tests/README.md``): depth 1 dispatches each MoE layer's routing arrays
one program ahead, leaves the freshly dispatched execute in flight behind
the next layer's host route, and samples on device -- and is
*token-identical* to depth 0, which reproduces the pre-PR-7 serial loop
bit for bit.  Covers both drivers (ServeLoop, ServeScheduler), both
dispatch backends (gather fused, bcsr two-phase), greedy and temperature
sampling, mid-run scheduler join/evict, the overlap accounting
(``route_hidden_frac`` is exactly 0 at depth 0), and the serial-mode
timing attribution split (``host_route_ms`` vs ``device_execute_ms``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import engine
from repro.models.config import ArchConfig
from repro.models import model as M
from repro.launch.serve import ServeLoop, ServeScheduler

TINY = ArchConfig(
    name="tiny-serve-pipe", family="moe", d_model=32, n_heads=2,
    n_kv_heads=1, d_ff=48, vocab_size=64, block_unit=("attn", "attn+moe"),
    n_repeats=2, head_dim=16, n_experts=4, top_k=1, capacity_factor=1.0,
    moe_shared_expert=True, policy="f32")

B, PROMPT, GEN = 2, 8, 6
MAX_SEQ = PROMPT + GEN


@pytest.fixture(scope="module")
def tiny_model():
    params = M.init_params(jax.random.PRNGKey(0), TINY)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT), 0,
                                 TINY.vocab_size)
    return params, prompts


# ------------------------------------------------------- StreamPipeline --


def test_stream_pipeline_depth_semantics():
    """Depth 0 blocks on push (the serial loop's block_until_ready); depth
    1 keeps exactly one handle in flight; drain() empties either."""
    pipe0 = engine.StreamPipeline(0)
    pipe0.push("a", jnp.ones((4,)) * 2)
    assert len(pipe0) == 0          # drained immediately: serial semantics
    pipe1 = engine.StreamPipeline(1)
    pipe1.push("a", jnp.ones((4,)))
    assert len(pipe1) == 1          # one execute rides in flight
    pipe1.push("b", jnp.ones((4,)) * 3)
    assert len(pipe1) == 1          # pushing the next blocks on the oldest
    pipe1.drain()
    assert len(pipe1) == 0 and not pipe1.busy()
    assert pipe1.pushes == 2
    with pytest.raises(ValueError):
        engine.StreamPipeline(2)


# ------------------------------------------------------ ServeLoop parity --


@pytest.mark.parametrize("dispatch", ["gather", "bcsr"])
def test_serve_loop_pipelined_token_parity(tiny_model, dispatch):
    """Greedy depth-1 tokens == depth-0 tokens, both backends.  The
    pipelined run ends with a single drain stat (its one decode-phase host
    sync) and dispatch-only decode steps."""
    params, prompts = tiny_model
    want = ServeLoop(params, TINY, max_seq=MAX_SEQ,
                     dispatch=dispatch).run(prompts, GEN)
    loop = ServeLoop(params, TINY, max_seq=MAX_SEQ, dispatch=dispatch,
                     pipeline_depth=1)
    got = loop.run(prompts, GEN)
    np.testing.assert_array_equal(got, want)
    s = loop.summary()
    assert s["pipeline"]["depth"] == 1
    assert s["drain"]["calls"] == 1
    assert all(st.extra.get("dispatch_only") for st in loop.stats
               if st.phase == "decode")
    if dispatch == "bcsr":
        # every decode execute was dispatch-only: nothing blocked mid-chain
        assert all(st.extra["dispatch_only"] for st in loop.stats
                   if st.phase == "execute" and st.step >= 0)


@pytest.mark.parametrize("dispatch", ["gather", "bcsr"])
def test_serve_loop_pipelined_temperature_parity(tiny_model, dispatch):
    """Temperature > 0: the on-device sampler consumes the same key chain
    as the serial host sampler, so the token streams are identical."""
    params, prompts = tiny_model
    want = ServeLoop(params, TINY, max_seq=MAX_SEQ, dispatch=dispatch,
                     temperature=0.7, sample_seed=7).run(prompts, GEN)
    got = ServeLoop(params, TINY, max_seq=MAX_SEQ, dispatch=dispatch,
                    temperature=0.7, sample_seed=7,
                    pipeline_depth=1).run(prompts, GEN)
    np.testing.assert_array_equal(got, want)


# -------------------------------------------------- ServeScheduler parity --

# (arrival_step, prompt_seed, prompt_len, max_new): staggered arrivals into
# max_slots=3 so requests join mid-run and evictions free slots mid-run
TRACE = [(0, 0, 6, 4), (0, 1, 4, 5), (2, 2, 8, 3), (3, 3, 5, 4),
         (5, 4, 7, 3), (6, 5, 3, 4)]


def _run_sched(params, dispatch, depth, temperature=0.0):
    sched = ServeScheduler(params, TINY, max_seq=MAX_SEQ, max_slots=3,
                           dispatch=dispatch, temperature=temperature,
                           sample_seed=11, pipeline_depth=depth,
                           cache_dtype=jnp.float32)
    rng = np.random.default_rng(42)
    pending = sorted(
        [(step, rng.integers(0, TINY.vocab_size, plen).astype(np.int32),
          gen) for step, _, plen, gen in TRACE], key=lambda t: t[0])
    while pending or sched.has_work():
        while pending and pending[0][0] <= sched.step_idx:
            _, prompt, gen = pending.pop(0)
            sched.submit(prompt, gen)
        sched.step()
    return sched


@pytest.mark.parametrize("dispatch", ["gather", "bcsr"])
def test_scheduler_pipelined_token_parity(tiny_model, dispatch):
    """Depth-1 continuous batching emits per-request token streams
    identical to depth 0, across mid-run joins and evictions (the batch
    composition changes while executes are in flight)."""
    params, _ = tiny_model
    a = _run_sched(params, dispatch, 0)
    b = _run_sched(params, dispatch, 1)
    want = {r.uid: list(r.tokens) for r in a.finished}
    got = {r.uid: list(r.tokens) for r in b.finished}
    assert len(want) == len(TRACE)
    assert got == want


def test_scheduler_pipelined_temperature_parity(tiny_model):
    """Per-request key chains survive the on-device vmapped sampler: the
    scheduler's depth-1 temperature tokens match depth 0 exactly."""
    params, _ = tiny_model
    a = _run_sched(params, "bcsr", 0, temperature=0.7)
    b = _run_sched(params, "bcsr", 1, temperature=0.7)
    assert ({r.uid: list(r.tokens) for r in b.finished}
            == {r.uid: list(r.tokens) for r in a.finished})


# --------------------------------------------------- overlap accounting --


def test_serial_mode_has_zero_hidden_route(tiny_model):
    """Depth 0 is the serial baseline by construction: no route time is
    ever counted as hidden, and no execute is dispatch-only."""
    params, prompts = tiny_model
    loop = ServeLoop(params, TINY, max_seq=MAX_SEQ, dispatch="bcsr")
    loop.run(prompts, GEN)
    s = loop.summary()
    assert s["pipeline"]["depth"] == 0
    assert s["timing"]["route_hidden_frac"] == 0.0
    assert s["timing"]["route_hidden_ms"] == 0.0
    assert s["timing"]["execute_dispatch_ms"] == 0.0
    for st in loop.stats:
        if st.phase == "route":
            assert st.extra["hidden_s"] == 0.0
            assert not st.extra["pipelined"]
        if st.phase == "execute":
            assert not st.extra["dispatch_only"]
    assert "drain" not in s


def test_pipelined_overlap_accounting_bounds(tiny_model):
    """Depth 1: hidden route time is a sub-interval of the route fetch
    wait (hidden_s <= wait_s per stat, so route_hidden_frac is in [0, 1]),
    and the blocked-execute column is empty for decode steps."""
    params, prompts = tiny_model
    loop = ServeLoop(params, TINY, max_seq=MAX_SEQ, dispatch="bcsr",
                     pipeline_depth=1)
    loop.run(prompts, GEN)
    s = loop.summary()
    tm = s["timing"]
    assert 0.0 <= tm["route_hidden_frac"] <= 1.0
    assert tm["route_hidden_ms"] <= tm["route_wait_ms"] + 1e-9
    for st in loop.stats:
        if st.phase == "route":
            assert 0.0 <= st.extra["hidden_s"] <= (
                st.extra.get("wait_s", 0.0) + 1e-9)
            # depth 1 never blocks on the attention half before routing
            assert st.extra["drain_s"] == 0.0


# ------------------------------------------------- timing attribution --


def test_serial_timing_attribution_sums_to_wall(tiny_model):
    """Satellite 2: in serial mode the phase components -- attention drain,
    host route, device execute, final logits wait -- are disjoint
    sub-intervals of the layered prefill/decode walls, so their sum is
    bounded by (and accounts for the bulk of) the pass wall-clock.
    Aggregated over all steps; generous tolerance for interpret-mode CPU
    timer noise."""
    params, prompts = tiny_model
    loop = ServeLoop(params, TINY, max_seq=MAX_SEQ, dispatch="bcsr")
    loop.run(prompts, GEN)
    loop.run(prompts, GEN)   # measure the warm run: stats reset per run
    s = loop.summary()
    wall = s["prefill"]["seconds"] + s["decode"]["seconds"]
    tm = s["timing"]
    logits_wait = sum(st.extra.get("logits_wait_s", 0.0)
                      for st in loop.stats if st.phase == "decode")
    parts = (tm["attn_drain_ms"] + tm["host_route_ms"]
             + tm["route_wait_ms"] + tm["device_execute_ms"]) / 1e3 \
        + logits_wait
    # components nest inside the pass timers: the sum can only fall short
    # of wall by the unattributed remainder (per-layer python glue + attn
    # dispatch, which dominates at this tiny d_model -- hence the loose
    # floor; the exact identities below are the sharp attribution check)
    assert parts <= wall + 5e-3
    assert parts >= 0.05 * wall
    # the split is exact by construction: host + wait == route phase
    route_s = s["route"]["seconds"]
    assert (tm["host_route_ms"] + tm["route_wait_ms"]) / 1e3 == \
        pytest.approx(route_s, rel=1e-9)
    assert tm["device_execute_ms"] / 1e3 == \
        pytest.approx(s["execute"]["seconds"], rel=1e-9)
