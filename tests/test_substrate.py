"""Substrate tests: data determinism, optimizer, checkpointing (incl. crash
tolerance), gradient compression, fault-tolerant trainer restarts."""
import dataclasses
import functools
import os
import shutil
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import SyntheticLM, Prefetcher
from repro.grad_comp.sparse_allreduce import (compress, compression_ratio,
                                              sparse_allreduce_tree,
                                              union_reduce)
from repro.models import model as M
from repro.optim.adamw import AdamW, cosine_schedule, global_norm
from repro.runtime.trainer import (SimulatedFailure, Trainer, TrainerConfig,
                                   run_with_restarts)

CFG = get_smoke("qwen3-1.7b")


# ------------------------------------------------------------------ data ----

def test_data_step_addressable_determinism():
    d1 = SyntheticLM(CFG, batch=4, seq_len=32, seed=7)
    d2 = SyntheticLM(CFG, batch=4, seq_len=32, seed=7)
    np.testing.assert_array_equal(d1.batch_at(13)["tokens"],
                                  d2.batch_at(13)["tokens"])
    assert not np.array_equal(d1.batch_at(13)["tokens"],
                              d1.batch_at(14)["tokens"])


def test_data_prefetcher():
    d = SyntheticLM(CFG, batch=2, seq_len=16, seed=1)
    pf = Prefetcher(d.stream(), depth=2)
    b1 = next(pf)
    b2 = next(pf)
    assert b1["tokens"].shape == (2, 16)
    assert not np.array_equal(b1["tokens"], b2["tokens"])
    pf.close()


def test_data_learnable_structure():
    d = SyntheticLM(CFG, batch=8, seq_len=64, seed=3, noise=0.0)
    toks = d.batch_at(0)["tokens"]
    # with zero noise, t_{i+1} == perm[t_i] exactly
    np.testing.assert_array_equal(toks[:, 1:], d.perm[toks[:, :-1]])


# ------------------------------------------------------------- optimizer ----

def test_adamw_reduces_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=None)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) < 1e-4
    assert abs(float(lr(jnp.asarray(10))) - 1e-3) < 1e-4
    assert float(lr(jnp.asarray(100))) < 2e-4


# ------------------------------------------------------------ checkpoint ----

def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    mgr.save(5, state, metadata={"loss": 1.5})
    like = jax.eval_shape(lambda: state)
    got, step = mgr.restore(like)
    assert step == 5
    np.testing.assert_array_equal(got["a"], state["a"])
    assert mgr.metadata(5)["loss"] == 1.5


def test_checkpoint_gc_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = {"x": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert sorted(mgr.all_steps()) == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_crash_tolerance(tmp_path):
    """A stale LATEST pointer (crash between rename and pointer write) must
    fall back to the newest complete step."""
    mgr = CheckpointManager(tmp_path, keep=3)
    state = {"x": jnp.zeros(2)}
    mgr.save(1, state)
    mgr.save(2, state)
    (tmp_path / "LATEST").write_text("99")      # corrupt pointer
    assert mgr.latest_step() == 2


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, jax, numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
sys.path.insert(0, "src")
from repro.checkpoint.manager import CheckpointManager
from repro.launch.mesh import compat_make_mesh

d = sys.argv[1]
mode = sys.argv[2]
mgr = CheckpointManager(d)
if mode == "save":
    mesh = compat_make_mesh((4, 2), ("data", "model"))
    w = jax.device_put(np.arange(64.0).reshape(8, 8),
                       NamedSharding(mesh, P("data", "model")))
    mgr.save(3, {"w": w})
else:  # restore on a DIFFERENT mesh shape
    mesh = compat_make_mesh((2, 4), ("data", "model"))
    sh = {"w": NamedSharding(mesh, P("model", "data"))}
    like = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float64)}
    got, step = mgr.restore(like, shardings=sh)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.arange(64.0).reshape(8, 8))
    print("ELASTIC_OK")
"""


def test_checkpoint_elastic_reshard(tmp_path):
    """Save on a 4x2 mesh, restore onto a 2x4 mesh with different specs."""
    env = dict(os.environ)
    for mode in ("save", "restore"):
        r = subprocess.run([sys.executable, "-c", ELASTIC_SCRIPT,
                            str(tmp_path), mode],
                           capture_output=True, text=True, env=env,
                           cwd=os.path.dirname(os.path.dirname(__file__)))
        assert r.returncode == 0, r.stderr
    assert "ELASTIC_OK" in r.stdout


# ------------------------------------------------------- grad compression ---

def test_topk_compress_error_feedback():
    g = jnp.asarray(np.random.default_rng(0).standard_normal(256), jnp.float32)
    keys, vals, err = compress(g, k=32)
    # kept + error reconstructs the gradient exactly
    from repro.core.su import stream_densify
    dense = stream_densify(keys, vals, jnp.asarray(32), 256)
    np.testing.assert_allclose(np.asarray(dense + err), np.asarray(g),
                               rtol=1e-6, atol=1e-6)


def test_union_reduce_equals_dense_sum():
    rng = np.random.default_rng(1)
    W, D, k = 4, 128, 16
    grads = rng.standard_normal((W, D)).astype(np.float32)
    keys = np.zeros((W, k), np.int32)
    vals = np.zeros((W, k), np.float32)
    dense_sum = np.zeros(D, np.float32)
    for w in range(W):
        idx = np.sort(rng.choice(D, k, replace=False)).astype(np.int32)
        keys[w], vals[w] = idx, grads[w, idx]
        dense_sum[idx] += grads[w, idx]
    ukeys, uvals, count = union_reduce(jnp.asarray(keys), jnp.asarray(vals))
    from repro.core.su import stream_densify
    got = stream_densify(ukeys, uvals, count, D)
    np.testing.assert_allclose(np.asarray(got), dense_sum, rtol=1e-5, atol=1e-5)


def test_sparse_allreduce_tree_mean():
    rng = np.random.default_rng(2)
    grads = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
    dense, errs = sparse_allreduce_tree(grads, k=64)  # k=D -> lossless
    np.testing.assert_allclose(np.asarray(dense),
                               np.asarray(grads.mean(0)), rtol=1e-5, atol=1e-5)
    assert float(jnp.abs(errs).max()) < 1e-6


def test_compression_ratio_accounting():
    assert compression_ratio(D=10_000_000, k=10_000, workers=16) > 30


# ---------------------------------------------------------------- trainer ---

def _make_step(cfg, opt):
    @jax.jit
    def step(params, opt_state, tokens):
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(p, tokens, cfg))(params)
        new_p, new_o = opt.update(grads, opt_state, params)
        return new_p, new_o, {"loss": loss, "grad_norm": global_norm(grads)}
    return step


def _trainer(tmp, cfg, opt, total=12, hook=None):
    data = SyntheticLM(cfg, batch=2, seq_len=16, seed=0)
    return Trainer(
        TrainerConfig(total_steps=total, ckpt_every=4, ckpt_dir=str(tmp),
                      log_every=1000),
        cfg, _make_step(cfg, opt), opt, data,
        init_state=lambda: M.init_params(jax.random.PRNGKey(0), cfg),
        failure_hook=hook)


def test_trainer_runs_and_checkpoints(tmp_path):
    cfg = dataclasses.replace(CFG, policy="f32")
    opt = AdamW(lr=1e-3)
    out = _trainer(tmp_path, cfg, opt).run()
    assert len(out["history"]) == 12
    assert CheckpointManager(tmp_path).latest_step() == 11


def test_trainer_restart_identical_trajectory(tmp_path):
    """Two injected failures; the stitched loss history must equal an
    uninterrupted run's exactly (determinism across restarts)."""
    cfg = dataclasses.replace(CFG, policy="f32")
    opt = AdamW(lr=1e-3)

    ref = _trainer(tmp_path / "ref", cfg, opt).run()

    crashes = {5: True, 9: True}

    def hook(step):
        if crashes.pop(step, None):
            raise SimulatedFailure(f"injected at {step}")

    losses = {}

    def make():
        t = _trainer(tmp_path / "ft", cfg, opt, hook=hook)
        orig_run = t.run
        def run():
            out = orig_run()
            return out
        t.run = run
        trainers.append(t)
        return t

    trainers = []
    out = run_with_restarts(make)
    assert out["restarts"] == 2
    stitched = {}
    for t in trainers:
        for step, loss in t.history:
            stitched[step] = loss
    ref_losses = dict(ref["history"])
    # compare the overlap from the last restart onwards (all steps covered)
    assert set(stitched) == set(ref_losses)
    for s in ref_losses:
        assert abs(stitched[s] - ref_losses[s]) < 1e-5, (s, stitched[s],
                                                         ref_losses[s])
