"""SpMSpM intersection kernel vs. oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.formats import random_dense_sparse
from repro.kernels.spmspm import ops
from repro.kernels.spmspm.ref import spmspm_ref, spmspm_gather_baseline

RNG = np.random.default_rng(11)


@pytest.mark.parametrize("density", [0.01, 0.1, 0.5])
@pytest.mark.parametrize("shape", [(16, 64, 16), (32, 128, 24)])
def test_spmspm_random(density, shape):
    R, K, C = shape
    a = random_dense_sparse(RNG, (R, K), 0.3)
    b = random_dense_sparse(RNG, (K, C), density)
    ak, av = ops.dense_to_ell_rows(a)
    bk, bv = ops.dense_to_ell_cols(b)
    got = ops.spmspm(ak, av, bk, bv, rt=8, ct=8, interpret=True)
    want = spmspm_ref(ak, av, bk, bv, inner=K)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_spmspm_vs_gather_baseline():
    a = random_dense_sparse(RNG, (16, 64), 0.2)
    b = random_dense_sparse(RNG, (64, 16), 0.05)
    ak, av = ops.dense_to_ell_rows(a)
    bk, bv = ops.dense_to_ell_cols(b)
    got = ops.spmspm(ak, av, bk, bv, interpret=True)
    base = spmspm_gather_baseline(ak, av, bk, bv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base), atol=1e-4)


def test_comparison_stats():
    a = random_dense_sparse(RNG, (8, 32), 0.5)
    b = random_dense_sparse(RNG, (32, 8), 0.5)
    ak, av = ops.dense_to_ell_rows(a)
    bk, bv = ops.dense_to_ell_cols(b)
    st = ops.comparison_stats(ak, bk)
    assert st["issued"] >= st["useful_upper"] >= 0
    assert st["issued"] == ak.shape[0] * bk.shape[0] * ak.shape[1] * bk.shape[1]


def test_compact_result_roundtrip():
    c = jnp.asarray(random_dense_sparse(RNG, (8, 8), 0.3))
    keys, vals, count = ops.compact_result(c, capacity=64)
    dense = np.zeros(64, np.float32)
    k = np.asarray(keys)[: int(count)]
    v = np.asarray(vals)[: int(count)]
    dense[k] = v
    np.testing.assert_allclose(dense.reshape(8, 8), np.asarray(c))
