"""Narrow-precision sparse pipeline: the BlockQuant bit-identity contract.

The contract under test (see tests/README.md "Narrow-precision contract"):

* **Kernels are bit-exact vs dequantize-then-f32.**  A quantized spmm /
  spmspm call (narrow fp8/int8 values + f32 scales, f32 resident
  accumulator) must produce *bit-identical* output to dequantizing the
  same container on host and running the wide f32 kernel -- the in-kernel
  dequant is ``values.astype(f32) * scale``, verbatim the host op order,
  followed by the identical dot.  ``assert_array_equal`` everywhere:
  single, batched, ragged-N, bucketed, sharded, any ``nt``.
* **Serving is tolerance-bounded.**  Quantizing the KV cache / expert
  weights changes values by construction; prefill *logits* stay bit-exact
  (quantization touches only the emitted cache), the first decode step is
  error-bounded, and the whole greedy rollout is token-stable for int8 on
  the smoke config.
* **Quantization is strictly opt-in**: scales=None containers and
  kv_quant=None serving paths execute the pre-quantization code
  byte-for-byte.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import precision
from repro.core.formats import (BCSR, BatchedBCSR, batched_bcsr_from_dense,
                                bcsr_from_dense)
from repro.kernels import engine
from repro.kernels.spmm import ops as spmm_ops
from repro.kernels.spmspm import ops as spmspm_ops

RNG = np.random.default_rng(7)
QUANT = ["fp8_e4m3", "fp8_e5m2", "int8"]


def _block_sparse(rng, shape, density, block=(8, 8)):
    gm, gn = shape[0] // block[0], shape[1] // block[1]
    mask = np.kron(rng.random((gm, gn)) < density, np.ones(block, bool))
    return np.where(mask, rng.standard_normal(shape), 0).astype(np.float32)


# ---------------------------------------------------------------------------
# quantize/dequantize helpers + stochastic rounding determinism
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", QUANT)
def test_quantize_blocks_round_trip_error_bounded(name):
    blocks = jnp.asarray(RNG.standard_normal((6, 8, 8)), jnp.float32)
    vals, scales = precision.quantize_blocks(blocks, name)
    assert vals.dtype == precision.QUANT_DTYPES[name]
    assert scales.shape == (6,) and scales.dtype == jnp.float32
    back = precision.dequantize_blocks(vals, scales)
    # relative error bounded by the format's step size at amax scale
    bound = {"fp8_e4m3": 0.07, "fp8_e5m2": 0.14, "int8": 0.005}[name]
    amax = jnp.abs(blocks).max(axis=(1, 2), keepdims=True)
    assert float(jnp.max(jnp.abs(back - blocks) / amax)) <= bound


def test_quantize_blocks_all_zero_block_gets_unit_scale():
    blocks = jnp.zeros((3, 8, 8), jnp.float32)
    vals, scales = precision.quantize_blocks(blocks, "fp8_e4m3")
    np.testing.assert_array_equal(np.asarray(scales), np.ones(3, np.float32))
    np.testing.assert_array_equal(
        np.asarray(precision.dequantize_blocks(vals, scales)),
        np.zeros((3, 8, 8), np.float32))


@pytest.mark.parametrize("name", QUANT)
def test_stochastic_round_deterministic_across_calls_and_jit(name):
    """Same seed -> bit-identical, eagerly and under jit; different seeds
    differ.  The SR key derives from fold_in(PRNGKey(seed), salt) -- no
    global RNG state anywhere."""
    x = jnp.asarray(RNG.standard_normal((256,)) * 3, jnp.float32)
    a = precision.stochastic_round(x, name, seed=5)
    b = precision.stochastic_round(x, name, seed=5)
    c = jax.jit(lambda v: precision.stochastic_round(v, name, seed=5))(x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
    d = precision.stochastic_round(x, name, seed=6)
    assert not (np.asarray(a) == np.asarray(d)).all()


def test_stochastic_round_quantize_blocks_deterministic():
    blocks = jnp.asarray(RNG.standard_normal((4, 8, 8)), jnp.float32)
    v1, s1 = precision.quantize_blocks(blocks, "fp8_e4m3",
                                       rounding="stochastic", seed=11)
    v2, s2 = precision.quantize_blocks(blocks, "fp8_e4m3",
                                       rounding="stochastic", seed=11)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


# ---------------------------------------------------------------------------
# container validation (construction-time dtype/shape consistency)
# ---------------------------------------------------------------------------

def test_bcsr_narrow_blocks_without_scales_rejected():
    a = bcsr_from_dense(_block_sparse(RNG, (64, 64), 0.2), (8, 8))
    with pytest.raises(ValueError, match="scales"):
        BCSR(indptr=a.indptr, block_rows=a.block_rows,
             block_cols=a.block_cols,
             blocks=a.blocks.astype(jnp.float8_e4m3fn),
             shape=a.shape, block=a.block)


def test_bcsr_scale_shape_mismatch_rejected():
    a = bcsr_from_dense(_block_sparse(RNG, (64, 64), 0.2), (8, 8))
    aq = a.quantize("int8")
    with pytest.raises(ValueError) as e:
        BCSR(indptr=aq.indptr, block_rows=aq.block_rows,
             block_cols=aq.block_cols, blocks=aq.blocks,
             shape=aq.shape, block=aq.block,
             scales=aq.scales[:-1])
    assert str(aq.blocks.shape[:1]) in str(e.value)  # shapes in the message


def test_batched_bcsr_scale_consistency_rejected():
    d = np.stack([_block_sparse(RNG, (64, 64), 0.2) for _ in range(3)])
    ab = batched_bcsr_from_dense(d, (8, 8))
    abq = ab.quantize("fp8_e4m3")
    with pytest.raises(ValueError, match="scales"):
        BatchedBCSR(indptr=abq.indptr, block_rows=abq.block_rows,
                    block_cols=abq.block_cols, blocks=abq.blocks,
                    shape=abq.shape, block=abq.block,
                    scales=abq.scales[:, :-1])
    with pytest.raises(ValueError, match="float32"):
        BatchedBCSR(indptr=abq.indptr, block_rows=abq.block_rows,
                    block_cols=abq.block_cols, blocks=abq.blocks,
                    shape=abq.shape, block=abq.block,
                    scales=abq.scales.astype(jnp.float16))


def test_quantize_dequantize_todense_consistent():
    dense = _block_sparse(RNG, (64, 64), 0.2)
    a = bcsr_from_dense(dense, (8, 8))
    aq = a.quantize("int8")
    np.testing.assert_array_equal(np.asarray(aq.todense()),
                                  np.asarray(aq.dequantize().todense()))


# ---------------------------------------------------------------------------
# spmm: bit-exact vs dequantize-then-f32 (the resident-accumulator contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", QUANT)
@pytest.mark.parametrize("nt", [1, 2, 4])
@pytest.mark.parametrize("N", [256, 130])   # aligned and ragged
def test_spmm_quant_bit_identical(name, nt, N):
    a = bcsr_from_dense(_block_sparse(RNG, (64, 64), 0.15), (8, 8))
    aq = a.quantize(name)
    b = jnp.asarray(RNG.standard_normal((64, N)), jnp.float32)
    got = spmm_ops.spmm(aq, b, nt=nt, interpret=True)
    want = spmm_ops.spmm(aq.dequantize(), b, nt=nt, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("name", QUANT)
def test_spmm_batched_quant_bit_identical(name):
    d = np.stack([_block_sparse(RNG, (64, 64), 0.15) for _ in range(3)])
    ab = batched_bcsr_from_dense(d, (8, 8)).quantize(name)
    b = jnp.asarray(RNG.standard_normal((3, 64, 128)), jnp.float32)
    got = spmm_ops.spmm_batched(ab, b, interpret=True)
    want = spmm_ops.spmm_batched(ab.dequantize(), b, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_spmm_bucketed_quant_bit_identical():
    """with_capacity pads the scale stream with 1.0 alongside the zero
    blocks; the padded quantized stream must still match exactly."""
    d = np.stack([_block_sparse(RNG, (64, 64), 0.15) for _ in range(2)])
    ab = batched_bcsr_from_dense(d, (8, 8)).quantize("fp8_e4m3")
    abq = ab.with_capacity(ab.nnzb + 16)
    assert abq.scales.shape == (2, ab.nnzb + 16)
    b = jnp.asarray(RNG.standard_normal((2, 64, 128)), jnp.float32)
    got = spmm_ops.spmm_batched(abq, b, interpret=True)
    want = spmm_ops.spmm_batched(abq.dequantize(), b, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.skipif(jax.device_count() < 2, reason="needs a >=2-device mesh")
@pytest.mark.parametrize("name", QUANT)
def test_shard_spmm_quant_bit_identical(name):
    a = bcsr_from_dense(_block_sparse(RNG, (64, 64), 0.15), (8, 8))
    aq = a.quantize(name)
    b = jnp.asarray(RNG.standard_normal((64, 256)), jnp.float32)
    mesh = jax.make_mesh((4,), ("data",))
    got = engine.shard_spmm(aq, b, mesh=mesh)
    want = spmm_ops.spmm(aq.dequantize(), b, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.skipif(jax.device_count() < 2, reason="needs a >=2-device mesh")
def test_shard_spmm_batched_quant_bit_identical():
    d = np.stack([_block_sparse(RNG, (64, 64), 0.15) for _ in range(4)])
    ab = batched_bcsr_from_dense(d, (8, 8)).quantize("int8")
    b = jnp.asarray(RNG.standard_normal((4, 64, 128)), jnp.float32)
    mesh = jax.make_mesh((4,), ("data",))
    got = engine.shard_spmm_batched(ab, b, mesh=mesh)
    want = spmm_ops.spmm_batched(ab.dequantize(), b, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_spmm_wide_path_ignores_quant_machinery():
    """scales=None containers run the pre-quantization path unchanged."""
    a = bcsr_from_dense(_block_sparse(RNG, (64, 64), 0.15), (8, 8))
    assert a.scales is None
    b = jnp.asarray(RNG.standard_normal((64, 128)), jnp.float32)
    out = spmm_ops.spmm(a, b, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(a.todense() @ b), atol=1e-4)


# ---------------------------------------------------------------------------
# spmspm: narrow A row streams
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", QUANT)
@pytest.mark.parametrize("nt", [1, 2])
def test_spmspm_quant_bit_identical(name, nt):
    from repro.core.formats import random_dense_sparse

    ad = random_dense_sparse(RNG, (32, 64), 0.2)
    bd = random_dense_sparse(RNG, (64, 32), 0.2)
    ak, av = spmspm_ops.dense_to_ell_rows(ad)
    bk, bv = spmspm_ops.dense_to_ell_cols(bd)
    qv, qs = precision.quantize_rows(jnp.asarray(av), name)
    dq = precision.dequantize_rows(qv, qs)
    got = spmspm_ops.spmspm(ak, qv, bk, bv, nt=nt, interpret=True,
                            a_scales=qs)
    want = spmspm_ops.spmspm(ak, dq, bk, bv, nt=nt, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.skipif(jax.device_count() < 2, reason="needs a >=2-device mesh")
def test_shard_spmspm_quant_bit_identical():
    from repro.core.formats import random_dense_sparse

    ad = random_dense_sparse(RNG, (32, 64), 0.2)
    bd = random_dense_sparse(RNG, (64, 64), 0.2)
    ak, av = spmspm_ops.dense_to_ell_rows(ad)
    bk, bv = spmspm_ops.dense_to_ell_cols(bd)
    qv, qs = precision.quantize_rows(jnp.asarray(av), "fp8_e4m3")
    dq = precision.dequantize_rows(qv, qs)
    mesh = jax.make_mesh((4,), ("data",))
    got = engine.shard_spmspm(ak, qv, bk, bv, mesh=mesh, a_scales=qs)
    want = spmspm_ops.spmspm(ak, dq, bk, bv, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# serving: quantized experts + quantized KV cache (tolerance-bounded)
# ---------------------------------------------------------------------------

TINY = dict(name="tiny-precision", family="moe", d_model=32, n_heads=2,
            n_kv_heads=1, d_ff=48, vocab_size=64,
            block_unit=("attn", "attn+moe"), n_repeats=2, head_dim=16,
            n_experts=4, top_k=1, capacity_factor=1.0,
            moe_shared_expert=True, policy="f32")


@pytest.fixture(scope="module")
def tiny_model():
    from repro.models.config import ArchConfig
    from repro.models import model as M

    cfg = ArchConfig(**TINY)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    return cfg, params, prompts


def test_quantized_experts_bit_identical_vs_dequantized(tiny_model):
    from repro.core.precision import QuantTensor
    from repro.models import moe

    cfg, params, _ = tiny_model
    ffn = jax.tree.map(lambda a: a[0], params["blocks"][1])["ffn"]
    qffn = moe.quantize_expert_weights(ffn, "fp8_e4m3")
    dffn = jax.tree.map(
        lambda w: w.dequantize(jnp.float32) if isinstance(w, QuantTensor)
        else w, qffn, is_leaf=lambda w: isinstance(w, QuantTensor))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 32), jnp.float32)
    out_q, _ = moe.apply_moe(qffn, x, cfg, counts=None, pos=None)
    out_d, _ = moe.apply_moe(dffn, x, cfg, counts=None, pos=None)
    np.testing.assert_array_equal(np.asarray(out_q), np.asarray(out_d))


def test_quantize_model_experts_requires_moe(tiny_model):
    from repro.models import moe

    cfg, params, _ = tiny_model
    no_moe = {"blocks": (params["blocks"][0],)}   # the dense-MLP attn slot
    with pytest.raises(ValueError, match="experts"):
        moe.quantize_model_experts(no_moe, "int8")


def test_kv_quant_prefill_logits_bit_exact(tiny_model):
    """kv_quant only changes the *emitted cache*: the prefill forward (and
    its logits) is bit-identical to the wide run."""
    from repro.models import model as M

    cfg, params, prompts = tiny_model
    lg_w, cache_w, _ = M.prefill(params, prompts, cfg, max_seq=14,
                                 cache_dtype=jnp.float32)
    lg_q, cache_q, _ = M.prefill(params, prompts, cfg, max_seq=14,
                                 cache_dtype=jnp.float32,
                                 kv_quant="fp8_e4m3")
    np.testing.assert_array_equal(np.asarray(lg_w), np.asarray(lg_q))
    leaf = cache_q["slots"][0]["attn"]
    assert set(leaf) == {"k", "k_scale", "v", "v_scale"}
    assert leaf["k"].dtype == jnp.float8_e4m3fn
    assert leaf["k_scale"].dtype == jnp.float32


@pytest.mark.parametrize("name", QUANT)
def test_kv_quant_first_decode_step_error_bounded(tiny_model, name):
    from repro.models import model as M

    cfg, params, prompts = tiny_model

    def first_step(kv_quant):
        lg, cache, pos = M.prefill(params, prompts, cfg, max_seq=14,
                                   cache_dtype=jnp.float32,
                                   kv_quant=kv_quant)
        tok = jnp.argmax(lg[:, -1:], axis=-1).astype(jnp.int32)
        out, _ = M.decode_step_layered(params, cfg, cache, int(pos), tok)
        return np.asarray(out)

    ref = first_step(None)
    got = first_step(name)
    rel = np.abs(got - ref).max() / max(np.abs(ref).max(), 1e-6)
    assert rel < 0.2, f"{name}: first-decode relative error {rel:.3f}"


@pytest.mark.serve
def test_kv_quant_int8_greedy_tokens_stable(tiny_model):
    """int8 KV + int8 experts reproduce the f32 loop's greedy tokens on the
    smoke config (the tightest quantizer; fp8 is tolerance-only)."""
    from repro.launch.serve import ServeLoop

    cfg, params, prompts = tiny_model
    base = ServeLoop(params, cfg, max_seq=14).run(prompts, 6)
    quant = ServeLoop(params, cfg, max_seq=14, quantize_experts="int8",
                      kv_quant="int8").run(prompts, 6)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(quant))


@pytest.mark.serve
def test_kv_quant_scheduler_matches_static_loop(tiny_model):
    """Continuous batching with a quantized cache pool: per-request tokens
    match the quantized static loop (per-row scatter of narrow values AND
    scales)."""
    from repro.launch.serve import ServeLoop, ServeScheduler

    cfg, params, prompts = tiny_model
    sched = ServeScheduler(params, cfg, max_seq=14, max_slots=2,
                           quantize_experts="int8", kv_quant="int8")
    r1 = sched.submit(np.asarray(prompts[0]), 6)
    r2 = sched.submit(np.asarray(prompts[1]), 6)
    out = sched.run()
    seq = ServeLoop(params, cfg, max_seq=14, quantize_experts="int8",
                    kv_quant="int8").run(prompts, 6)
    np.testing.assert_array_equal(np.asarray(out[r1.uid]), np.asarray(seq[0]))
    np.testing.assert_array_equal(np.asarray(out[r2.uid]), np.asarray(seq[1]))


# ---------------------------------------------------------------------------
# checkpoint: lossless quantized round-trip
# ---------------------------------------------------------------------------

def test_checkpoint_quantized_round_trip(tmp_path):
    """np.savez degrades ml_dtypes (bf16/fp8) leaves to void records; the
    manager byte-packs them, so narrow params restore bit-exact with their
    true dtypes (QuantTensor leaves ride the pytree)."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.core.precision import QuantTensor, quantize_tensor

    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 16))
    state = {"wide": x.astype(jnp.float32),
             "bf16": x.astype(jnp.bfloat16),
             "qt": quantize_tensor(x, "fp8_e4m3", axis=-2),
             "int8q": quantize_tensor(x, "int8", axis=-1)}
    mgr = CheckpointManager(tmp_path)
    mgr.save(0, state)
    like = jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype), state)
    restored, step = mgr.restore(like)
    assert step == 0
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    q = restored["qt"]
    assert isinstance(q, QuantTensor) and q.axis == -2
    assert q.values.dtype == jnp.float8_e4m3fn
