"""Per-arch smoke tests: reduced configs, one forward/train step on CPU,
shape + finiteness assertions, and prefill-vs-decode consistency.

Slow tier (minutes per arch on CPU): deselected from the default run,
enable with ``--run-slow`` (see tests/README.md)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_smoke
from repro.models import model as M

pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B=2, S=16):
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    emb = None
    if cfg.frontend != "none":
        emb = jax.random.normal(jax.random.PRNGKey(2),
                                (B, cfg.frontend_tokens, cfg.d_model),
                                jnp.float32) * 0.02
    return tokens, emb


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finite(name):
    cfg = get_smoke(name)
    params = M.init_params(KEY, cfg)
    tokens, emb = _inputs(cfg)
    logits = M.forward(params, tokens, cfg, embeddings=emb)
    S_total = tokens.shape[1] + (emb.shape[1] if emb is not None else 0)
    assert logits.shape == (2, S_total, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), f"{name}: non-finite logits"


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_grads_finite(name):
    cfg = get_smoke(name)
    params = M.init_params(KEY, cfg)
    tokens, emb = _inputs(cfg, B=2, S=12)
    loss, grads = jax.value_and_grad(M.loss_fn)(params, tokens, cfg,
                                                embeddings=emb)
    assert bool(jnp.isfinite(loss)), f"{name}: loss {loss}"
    leaves = jax.tree.leaves(grads)
    assert leaves and all(bool(jnp.isfinite(g).all()) for g in leaves), \
        f"{name}: non-finite grads"


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_matches_prefill(name):
    """Greedy decode step-by-step must reproduce the teacher-forced forward."""
    cfg = get_smoke(name)
    params = M.init_params(KEY, cfg)
    B, S = 1, 10
    tokens, _ = _inputs(cfg, B=B, S=S)
    full = M.forward(params, tokens, cfg)              # (B, S, V)

    cache = M.init_cache(cfg, batch=B, max_seq=S + 4)
    outs = []
    for t in range(S):
        logits, cache = M.decode_step(params, cfg, cache,
                                      jnp.asarray(t, jnp.int32),
                                      tokens[:, t: t + 1])
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)                       # (B, S, V)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=6e-2, rtol=6e-2)


def test_local_ring_buffer_matches_full_window():
    """gemma3-style local attention: ring-buffer decode == windowed prefill."""
    cfg = get_smoke("gemma3-12b")
    params = M.init_params(KEY, cfg)
    B, S = 1, 24  # > window=16 so the ring wraps
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab_size)
    full = M.forward(params, tokens, cfg)
    cache = M.init_cache(cfg, batch=B, max_seq=S)
    outs = []
    for t in range(S):
        logits, cache = M.decode_step(params, cfg, cache,
                                      jnp.asarray(t, jnp.int32),
                                      tokens[:, t: t + 1])
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=6e-2, rtol=6e-2)
