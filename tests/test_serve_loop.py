"""ServeLoop: the two-phase route-then-compile serving loop.

Tier-1 coverage runs on a tiny MoE config (seconds, CPU): token-for-token
parity of the ServeLoop against the pre-refactor serving loop (fused jit
decode), token parity of the two-phase bcsr path against the gather
baseline, and the bucket law on the phase-2 compile cache.  The full
smoke-arch loop is ``@pytest.mark.serve`` -- tiered out of the default
selection like ``slow`` (enable with ``--run-serve`` or ``-m serve``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ArchConfig
from repro.models import model as M
from repro.launch.serve import ServeLoop

TINY = ArchConfig(
    name="tiny-serve", family="moe", d_model=32, n_heads=2, n_kv_heads=1,
    d_ff=48, vocab_size=64, block_unit=("attn", "attn+moe"), n_repeats=2,
    head_dim=16, n_experts=4, top_k=1, capacity_factor=1.0,
    moe_shared_expert=True, policy="f32")

B, PROMPT, GEN = 2, 8, 6
MAX_SEQ = PROMPT + GEN


@pytest.fixture(scope="module")
def tiny_model():
    params = M.init_params(jax.random.PRNGKey(0), TINY)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT), 0,
                                 TINY.vocab_size)
    return params, prompts


def _old_style_loop(params, cfg, prompts, gen):
    """The pre-ServeLoop smoke loop, verbatim semantics: jit fused decode,
    greedy argmax."""
    logits, cache, pos = M.prefill(params, prompts, cfg, max_seq=MAX_SEQ)
    nxt = jnp.argmax(logits[:, -1, :cfg.vocab_size],
                     axis=-1)[:, None].astype(jnp.int32)
    decode = jax.jit(lambda p, c, pos, tok: M.decode_step(p, cfg, c, pos, tok))
    toks = [nxt]
    for i in range(gen - 1):
        lg, cache = decode(params, cache, pos + i, nxt)
        nxt = jnp.argmax(lg[:, -1, :cfg.vocab_size],
                         axis=-1)[:, None].astype(jnp.int32)
        toks.append(nxt)
    return np.asarray(jnp.concatenate(toks, axis=1))


def test_serve_loop_fused_matches_old_loop(tiny_model):
    """ServeLoop in fused mode is token-for-token the old serving loop."""
    params, prompts = tiny_model
    want = _old_style_loop(params, TINY, prompts, GEN)
    loop = ServeLoop(params, TINY, max_seq=MAX_SEQ)
    assert not loop.two_phase  # gather default = fused mode
    got = loop.run(prompts, GEN)
    np.testing.assert_array_equal(got, want)
    s = loop.summary()
    assert s["decode"]["calls"] == GEN - 1
    assert s["prefill"]["seconds"] > 0 and s["decode"]["seconds"] > 0


def test_serve_loop_two_phase_token_parity(tiny_model):
    """bcsr two-phase decode generates the same tokens as the gather fused
    loop (the backends are bit-identical per layer), while streaming
    bucketed -- not full-grid -- index streams and compiling phase 2 a
    bounded number of times."""
    params, prompts = tiny_model
    want = _old_style_loop(params, TINY, prompts, GEN)
    loop = ServeLoop(params, TINY, max_seq=MAX_SEQ, dispatch="bcsr")
    assert loop.two_phase  # auto-enabled: moe arch + bcsr backend
    got = loop.run(prompts, GEN)
    np.testing.assert_array_equal(got, want)

    s = loop.summary()
    # prefill AND every decode step routed + executed every attn+moe layer
    # (prefill rides the layered bucketed-stream path too since PR 5)
    n_moe_layers = sum(k == "attn+moe" for k in TINY.block_unit) * TINY.n_repeats
    assert s["route"]["calls"] == GEN * n_moe_layers
    assert s["execute"]["calls"] == s["route"]["calls"]
    # phase-2 compiles are keyed on the bucket: one signature for the whole
    # single-token decode phase plus one for the prefill token shape, never
    # one per step
    assert s["compile_signatures"] < s["execute"]["calls"]
    assert s["compile_signatures"] <= 3
    prefill_routes = [st for st in loop.stats
                      if st.phase == "route" and st.step == -1]
    assert len(prefill_routes) == n_moe_layers  # prefill streamed, not grid

    # a second run on the same loop resets generation state: its prefill
    # routes are labeled step -1 again, not with the stale last step index
    got2 = loop.run(prompts, GEN)
    np.testing.assert_array_equal(got2, want)
    prefill_routes2 = [st for st in loop.stats
                       if st.phase == "route" and st.step == -1]
    assert len(prefill_routes2) == n_moe_layers
    routes = [st for st in loop.stats if st.phase == "route"]
    for st in routes:
        assert st.extra["nnzb_stream"] <= max(
            2 * st.extra["nnzb_covered"], st.extra["bucket"])


def test_serve_loop_two_phase_decode_equals_layered_reference(tiny_model):
    """The layered decode path (what two-phase mode drives) reproduces the
    scanned decode_step logits."""
    params, prompts = tiny_model
    logits, cache, pos = M.prefill(params, prompts, TINY, max_seq=MAX_SEQ,
                                   cache_dtype=jnp.float32)
    tok = jnp.argmax(logits[:, -1, :TINY.vocab_size],
                     axis=-1)[:, None].astype(jnp.int32)
    want, want_cache = M.decode_step(params, TINY, cache, pos, tok,
                                     dtype=jnp.float32)
    got, got_cache = M.decode_step_layered(params, TINY, cache, int(pos),
                                           tok, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5),
        got_cache, want_cache)


def test_serve_loop_temperature_sampling_runs(tiny_model):
    """Temperature > 0 exercises the categorical path deterministically
    (fixed sample_seed): same loop twice = same tokens."""
    params, prompts = tiny_model
    a = ServeLoop(params, TINY, max_seq=MAX_SEQ, temperature=0.7,
                  sample_seed=7).run(prompts, GEN)
    b = ServeLoop(params, TINY, max_seq=MAX_SEQ, temperature=0.7,
                  sample_seed=7).run(prompts, GEN)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (B, GEN)


@pytest.mark.serve
@pytest.mark.parametrize("dispatch", ["gather", "bcsr"])
def test_serve_loop_smoke_arch(dispatch):
    """Full smoke-config serving loop on a real MoE arch, both backends,
    two-phase auto-selected for bcsr.  Tiered behind --run-serve."""
    from repro.configs import get_smoke

    cfg = get_smoke("llama4-scout-17b-a16e")
    cfg = dataclasses.replace(cfg, moe_dispatch=dispatch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    loop = ServeLoop(params, cfg, max_seq=16)
    gen = loop.run(prompts, 4)
    assert gen.shape == (2, 4)
    assert (gen >= 0).all() and (gen < cfg.vocab_size).all()
    if dispatch == "bcsr":
        assert loop.two_phase and loop.summary()["compile_signatures"] >= 1
