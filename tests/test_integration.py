"""Integration tests: kernel-path wiring, prefill->decode handoff, dry-run
machinery on a tiny in-process mesh (subprocess), grad-compressed training.

Slow tier (model compiles + subprocess dry-runs): deselected from the
default run, enable with ``--run-slow`` (see tests/README.md)."""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import model as M

pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


def test_model_forward_kernel_impl_matches_chunked():
    """The Pallas flash kernel (interpret mode) wired through the full model
    must match the chunked-jnp path."""
    cfg = dataclasses.replace(get_smoke("qwen3-1.7b"), policy="f32")
    params = M.init_params(KEY, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                cfg.vocab_size)
    # interpret=True is the default lowering on CPU inside the kernel wrapper
    import repro.kernels.flash_attention.ops as fops
    orig = fops.attention

    def interp_attention(*a, **k):
        k["interpret"] = True
        return orig(*a, **k)

    fops.attention = interp_attention
    try:
        lk = M.forward(params, tokens, cfg, impl="kernel")
    finally:
        fops.attention = orig
    lc = M.forward(params, tokens, cfg, impl="chunked")
    np.testing.assert_allclose(np.asarray(lk), np.asarray(lc), atol=2e-3,
                               rtol=2e-3)


@pytest.mark.parametrize("name", ["qwen2-1.5b", "zamba2-1.2b", "rwkv6-7b",
                                  "gemma3-12b", "llama4-scout-17b-a16e"])
def test_prefill_then_decode_matches_full_forward(name):
    """prefill(prompt) -> decode_step xN must equal teacher-forced forward."""
    cfg = get_smoke(name)
    params = M.init_params(KEY, cfg)
    B, S_prompt, S_gen = 1, 8, 6
    S = S_prompt + S_gen
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab_size)
    full = M.forward(params, tokens, cfg)

    logits, cache, pos = M.prefill(params, tokens[:, :S_prompt], cfg,
                                   max_seq=S)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, S_prompt - 1]),
                               atol=8e-2, rtol=8e-2)
    outs = []
    for t in range(S_prompt, S):
        step_logits, cache = M.decode_step(
            params, cfg, cache, jnp.asarray(t, jnp.int32), tokens[:, t: t + 1])
        outs.append(step_logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec),
                               np.asarray(full[:, S_prompt:]),
                               atol=8e-2, rtol=8e-2)


DRYRUN_SMOKE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax
from jax.sharding import NamedSharding, PartitionSpec as P
import dataclasses
from repro.configs import get_smoke
from repro.launch import steps as St
from repro.launch.shapes import ShapeSpec
from repro.launch.hlo_analysis import analyze

from repro.launch.mesh import compat_make_mesh, mesh_context

cfg = get_smoke("llama4-scout-17b-a16e")
shape = ShapeSpec("tiny_train", "train", 32, 8)
mesh = compat_make_mesh((2, 4), ("data", "model"))
with mesh_context(mesh):
    opt = St.default_optimizer()
    step, (p_s, o_s, tok_s, emb_s), out_s = St.make_train_step(
        cfg, shape, mesh, opt, seq_chunk=16)
    params = St.abstract_params(cfg)
    ps = jax.tree.map(lambda s: NamedSharding(mesh, s), p_s,
                      is_leaf=lambda x: isinstance(x, P))
    params = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        params, ps, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    opt_state = jax.eval_shape(opt.init, params)
    os_ = jax.tree.map(lambda s: NamedSharding(mesh, s),
                       St.AdamWState(m=p_s, v=p_s, count=P(), master=None),
                       is_leaf=lambda x: isinstance(x, P))
    opt_state = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        opt_state, os_, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    tokens = jax.ShapeDtypeStruct((8, 32), jax.numpy.int32,
                                  sharding=NamedSharding(mesh, tok_s))
    compiled = jax.jit(step).lower(params, opt_state, tokens).compile()
    acc = analyze(compiled.as_text())
    assert acc["dot_flops"] > 0
    assert compiled.memory_analysis().temp_size_in_bytes > 0
    print("DRYRUN_SMOKE_OK", int(acc["dot_flops"]))
"""


def test_dryrun_machinery_small_mesh():
    """Full dry-run path (train step, shardings, HLO accounting) on an
    8-device fake mesh in a subprocess (keeps this process at 1 device)."""
    r = subprocess.run([sys.executable, "-c", DRYRUN_SMOKE],
                       capture_output=True, text=True,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "DRYRUN_SMOKE_OK" in r.stdout


def test_grad_compressed_training_learns():
    """Top-k sparse-gradient training (SU union path) still reduces loss."""
    from repro.data.pipeline import SyntheticLM
    from repro.launch.train import make_step
    from repro.optim.adamw import AdamW
    cfg = dataclasses.replace(get_smoke("qwen3-1.7b"), policy="f32")
    opt = AdamW(lr=3e-3)
    step = make_step(cfg, opt, grad_compress_k=2048)
    params = M.init_params(KEY, cfg)
    state = opt.init(params)
    data = SyntheticLM(cfg, batch=4, seq_len=32, seed=0, noise=0.0)
    losses = []
    for i in range(30):
        b = data.batch_at(i)
        params, state, metrics = step(params, state, jnp.asarray(b["tokens"]))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


CVJP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.models.layers import chunked_attention, flash_fwd_chunked_bwd
from repro.parallel import context as pctx
from repro.launch.mesh import compat_make_mesh, mesh_context
mesh = compat_make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
q = jnp.asarray(rng.standard_normal((2, 4, 256, 32)), jnp.float32)
k = jnp.asarray(rng.standard_normal((2, 2, 256, 32)), jnp.float32)
v = jnp.asarray(rng.standard_normal((2, 2, 256, 32)), jnp.float32)
with mesh_context(mesh):
    with pctx.activation_specs(mesh=mesh):
        f = flash_fwd_chunked_bwd(True, None)
        gk = jax.grad(lambda q, k, v: (f(q, k, v) ** 2).sum(),
                      argnums=(0, 1, 2))(q, k, v)
        gc = jax.grad(lambda q, k, v: (chunked_attention(
            q, k, v, causal=True) ** 2).sum(), argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gk, gc):
            assert float(jnp.abs(a - b).max()) < 2e-3
print("CVJP_OK")
"""


def test_flash_fwd_chunked_bwd_grads_match():
    """Kernel-forward/chunked-backward custom_vjp == pure-chunked grads
    (run on a fake 8-device mesh in a subprocess)."""
    r = subprocess.run([sys.executable, "-c", CVJP_SCRIPT],
                       capture_output=True, text=True,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "CVJP_OK" in r.stdout
