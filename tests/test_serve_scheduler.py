"""ServeScheduler: continuous-batching multi-tenant serving.

Tier-1 coverage on the tiny MoE config (seconds, CPU).  The load-bearing
contract is **composition independence**: a request's generated tokens must
not depend on which neighbours share the batch, when it was admitted, or
which slot it landed in -- so a join/evict schedule with staggered arrivals
is token-identical to running each request alone through a sequential
``ServeLoop`` (both dispatch backends).  Plus the serving-state correctness
fixes this PR ships: KV-cache overflow raises instead of silently clamping,
seeded ``run()`` calls are bit-identical, and the batch-bucket law bounds
the compiled step shapes.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import engine
from repro.models.config import ArchConfig
from repro.models import model as M
from repro.launch.serve import ServeLoop, ServeScheduler

TINY = ArchConfig(
    name="tiny-serve", family="moe", d_model=32, n_heads=2, n_kv_heads=1,
    d_ff=48, vocab_size=64, block_unit=("attn", "attn+moe"), n_repeats=2,
    head_dim=16, n_experts=4, top_k=1, capacity_factor=1.0,
    moe_shared_expert=True, policy="f32")

MAX_SEQ = 24


@pytest.fixture(scope="module")
def tiny_model():
    params = M.init_params(jax.random.PRNGKey(0), TINY)
    rng = np.random.default_rng(0)
    # mixed prompt/generation lengths: the trace that forces join/evict
    reqs = [(rng.integers(0, TINY.vocab_size, int(rng.integers(4, 10))),
             int(rng.integers(3, 8))) for _ in range(5)]
    return params, reqs


def _sequential_reference(params, reqs, dispatch):
    """Each request alone through a sequential ServeLoop (same max_seq, so
    the decode cache geometry matches the scheduler's slot rows)."""
    out = []
    for prompt, gen in reqs:
        loop = ServeLoop(params, TINY, max_seq=MAX_SEQ, dispatch=dispatch)
        out.append(loop.run(jnp.asarray(prompt[None, :], jnp.int32), gen)[0])
    return out


@pytest.mark.parametrize("dispatch", ["gather", "bcsr"])
def test_scheduler_matches_sequential(tiny_model, dispatch):
    """Continuous batching with staggered arrivals, join/evict, and a slot
    pool smaller than the request count is token-identical per request to
    sequential single-request serving."""
    params, reqs = tiny_model
    want = _sequential_reference(params, reqs, dispatch)

    sched = ServeScheduler(params, TINY, max_seq=MAX_SEQ, max_slots=2,
                           dispatch=dispatch)
    assert sched.two_phase == (dispatch == "bcsr")
    for prompt, gen in reqs[:3]:
        sched.submit(prompt, gen)
    late_submitted = False
    while sched.has_work():
        sched.step()
        if sched.step_idx == 2 and not late_submitted:
            for prompt, gen in reqs[3:]:     # arrivals mid-flight
                sched.submit(prompt, gen)
            late_submitted = True
    gen_map = sched.run()   # drains nothing further; returns uid -> tokens
    assert len(gen_map) == len(reqs)
    for uid, tokens in gen_map.items():
        np.testing.assert_array_equal(tokens, want[uid])
        assert len(tokens) == reqs[uid][1]

    # the pool saturated (2 slots, 5 requests): evictions freed slots that
    # later admissions reused
    assert any(s.extra.get("active") == 2 for s in sched.stats
               if s.phase == "decode")
    prefills = [s for s in sched.stats if s.phase == "prefill"]
    assert len(prefills) == len(reqs)


def test_scheduler_batch_bucket_law(tiny_model):
    """Decode-step batch shapes are power-of-two buckets, and (two-phase)
    phase-2 compile signatures stay bounded by the bucket product, never
    one per batch-composition change."""
    params, reqs = tiny_model
    sched = ServeScheduler(params, TINY, max_seq=MAX_SEQ, max_slots=3,
                           dispatch="bcsr")
    # allocation is itself bucketed: 3 requested slots -> 4 rows
    assert sched.n_slots == 4
    for prompt, gen in reqs:
        sched.submit(prompt, gen)
    sched.run()
    assert sched.batch_buckets <= {1, 2, 4}
    for s in sched.stats:
        if s.phase == "decode":
            b = s.extra["batch_bucket"]
            assert b == engine.batch_bucket(b)   # a fixed point = a pow2
            assert s.extra["active"] <= b
    summ = sched.summary()
    # signature bound: (decode batch buckets + prefill) x nnzb buckets x
    # token shapes (S=1 decode + distinct prompt lengths)
    n_prompt_shapes = len({len(p) for p, _ in reqs})
    bound = ((len(summ["batch_buckets"]) + 1)
             * max(1, len(summ["nnzb_buckets"])) * (n_prompt_shapes + 1))
    assert summ["compile_signatures"] <= bound
    assert summ["decode"]["tok_per_s"] > 0
    assert summ["token_latency_ms"]["p50"] <= summ["token_latency_ms"]["p99"]


def test_scheduler_eos_eviction(tiny_model):
    """A request whose next token is its eos_id evicts immediately and
    frees the slot for the queue."""
    params, reqs = tiny_model
    prompt, gen = reqs[0]
    # find the first greedy token, then use it as the eos of a second run
    probe = ServeScheduler(params, TINY, max_seq=MAX_SEQ, max_slots=1)
    probe.submit(prompt, 4)
    first = probe.run()[0][0]

    sched = ServeScheduler(params, TINY, max_seq=MAX_SEQ, max_slots=1)
    sched.submit(prompt, 4, eos_id=int(first))
    sched.submit(reqs[1][0], 2)
    out = sched.run()
    assert len(out[0]) == 1 and out[0][0] == first   # stopped at eos
    assert len(out[1]) == 2                          # queued request served


def test_scheduler_overflow_guard(tiny_model):
    """Admission refuses requests that could never fit; the decode-step
    guard is the backstop for direct state corruption."""
    params, _ = tiny_model
    sched = ServeScheduler(params, TINY, max_seq=10, max_slots=1)
    with pytest.raises(ValueError, match="never be served"):
        sched.submit(np.arange(8, dtype=np.int32), 8)
    # corrupt the state by hand to prove the decode-step backstop fires
    req = sched.submit(np.arange(4, dtype=np.int32), 2)
    sched.admit()
    req.pos = sched.max_seq
    with pytest.raises(RuntimeError, match="KV-cache overflow"):
        sched.decode_step()


def test_scheduler_temperature_reproducible(tiny_model):
    """Per-request sampling keys: the same trace served twice (even with a
    different slot pool, hence different batch composition) generates
    bit-identical tokens per request."""
    params, reqs = tiny_model

    def serve(max_slots):
        sched = ServeScheduler(params, TINY, max_seq=MAX_SEQ,
                               max_slots=max_slots, temperature=0.7,
                               sample_seed=11)
        for prompt, gen in reqs:
            sched.submit(prompt, gen)
        return sched.run()

    a, b, c = serve(2), serve(2), serve(4)
    for uid in a:
        np.testing.assert_array_equal(a[uid], b[uid])
        np.testing.assert_array_equal(a[uid], c[uid])


def test_vector_pos_decode_matches_scalar(tiny_model):
    """The per-row-position decode path (what the scheduler drives) is
    bit-identical to the scalar path when every row sits at the same
    position -- scalar and vector pos are the same function."""
    params, _ = tiny_model
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 TINY.vocab_size)
    logits, cache, pos = M.prefill(params, prompts, TINY, max_seq=MAX_SEQ,
                                   cache_dtype=jnp.float32)
    tok = jnp.argmax(logits[:, -1, :TINY.vocab_size],
                     axis=-1)[:, None].astype(jnp.int32)
    want, want_cache = M.decode_step(params, TINY, cache, int(pos), tok)
    pos_vec = np.full((2,), int(pos), np.int32)
    got, got_cache = M.decode_step(params, TINY, cache, pos_vec, tok)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        got_cache, want_cache)
