"""Multi-tile output residency (``nt``): the tier-1 parity suite.

Contract (tests/README.md "Residency & overlap contract"): widening the
VMEM-resident accumulator to ``nt`` N-tiles changes ONLY how often the
index/block stream is re-walked -- never a single output bit.  Per output
element the accumulation order is the stream order for any ``nt``, so every
test here uses ``assert_array_equal`` against ``nt=1``, including ragged
``N % (nt*bn) != 0`` shapes, the trace-safe bucketed stream entry, and the
sharded engine wrappers.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.formats import (batched_bcsr_from_dense, bcsr_from_dense,
                                random_dense_sparse)
from repro.kernels import engine, tuning
from repro.kernels.spmm import ops as spmm_ops
from repro.kernels.spmm.kernel import stream_walks
from repro.kernels.spmm.ref import spmm_ref
from repro.kernels.spmspm import ops as spmspm_ops
from repro.kernels.spmspm.ref import spmspm_ref

RNG = np.random.default_rng(11)


def _mesh(n):
    return jax.make_mesh((n,), ("data",))


# ---------------------------------------------------------------------------
# spmm_bcsr: nt-wide accumulator
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nt", [2, 4])
@pytest.mark.parametrize("N", [512, 500, 130])  # incl. N % (nt*bn) != 0
def test_spmm_nt_bit_identical(nt, N):
    a_dense = random_dense_sparse(RNG, (128, 96), 0.2)
    a = bcsr_from_dense(a_dense, (8, 8))
    b = jnp.asarray(RNG.standard_normal((96, N)), jnp.float32)
    want = spmm_ops.spmm(a, b, bn=128, nt=1, interpret=True)
    got = spmm_ops.spmm(a, b, bn=128, nt=nt, interpret=True)
    assert got.shape == (128, N)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_allclose(np.asarray(got), np.asarray(spmm_ref(a, b)),
                               atol=1e-4, rtol=1e-4)


def test_spmm_nt_empty_rows_and_batched():
    """Row-coverage padding and the vmapped batched kernel hold under nt."""
    a_dense = np.zeros((64, 64), np.float32)
    a_dense[9, :16] = 1.0
    a = bcsr_from_dense(a_dense, (8, 8))
    b = jnp.asarray(RNG.standard_normal((64, 256)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(spmm_ops.spmm(a, b, bn=128, nt=2, interpret=True)),
        np.asarray(spmm_ops.spmm(a, b, bn=128, nt=1, interpret=True)))

    stack = np.stack([random_dense_sparse(RNG, (64, 64), 0.15)
                      for _ in range(3)])
    ab = batched_bcsr_from_dense(stack, (8, 8))
    d = jnp.asarray(RNG.standard_normal((3, 64, 384)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(spmm_ops.spmm_batched(ab, d, bn=128, nt=2,
                                         interpret=True)),
        np.asarray(spmm_ops.spmm_batched(ab, d, bn=128, nt=1,
                                         interpret=True)))


def test_spmm_nt_validation_and_walks():
    a = bcsr_from_dense(random_dense_sparse(RNG, (32, 32), 0.4), (8, 8))
    b = jnp.asarray(RNG.standard_normal((32, 128)), jnp.float32)
    with pytest.raises(ValueError, match="nt=0"):
        spmm_ops.spmm(a, b, nt=0, interpret=True)
    ak, av = spmspm_ops.dense_to_ell_rows(np.eye(8, dtype=np.float32))
    with pytest.raises(ValueError, match="nt=0"):
        spmspm_ops.spmspm(ak, av, ak, av, rt=8, ct=8, nt=0, interpret=True)
    # the reread invariant the benchmarks report
    assert stream_walks(512, 128, 1) == 4
    assert stream_walks(512, 128, 4) == 1
    assert stream_walks(500, 128, 2) == 2


def test_tuning_nt_clamps():
    """The table's nt clamps to the operand: a supertile wider than N is
    pure padding; CPU rows pin nt=1."""
    t = tuning.spmm_tiles(1024, jnp.float32)
    assert t["nt"] >= 1 and t["bn"] >= tuning.LANE
    assert tuning.spmm_tiles(128, jnp.float32)["nt"] == 1  # one tile fits all
    assert tuning.moe_dispatch_tiles(64, jnp.float32)["nt"] == 1
    assert tuning.spmspm_nt(8, 8, 4, jnp.float32) == 1


# ---------------------------------------------------------------------------
# sharded engine wrappers
# ---------------------------------------------------------------------------

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 2, reason="needs a >=2-device mesh "
    "(set XLA_FLAGS=--xla_force_host_platform_device_count=4)")


@needs_mesh
@pytest.mark.parametrize("N", [512, 320])
def test_shard_spmm_nt_matches_single_device(N):
    a = bcsr_from_dense(random_dense_sparse(RNG, (64, 64), 0.2), (8, 8))
    b = jnp.asarray(RNG.standard_normal((64, N)), jnp.float32)
    want = spmm_ops.spmm(a, b, bn=128, nt=1, interpret=True)
    got = engine.shard_spmm(a, b, mesh=_mesh(2), bn=128, nt=2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@needs_mesh
def test_shard_spmm_batched_stream_nt_trace_safe():
    """The phase-2 entry stays trace-safe with a widened accumulator, and
    the bucketed wrapper threads nt through."""
    stack = np.stack([random_dense_sparse(RNG, (32, 32), 0.3)
                      for _ in range(2)])
    a = spmm_ops.pad_empty_rows(batched_bcsr_from_dense(stack, (8, 8)))
    d = jnp.asarray(RNG.standard_normal((2, 32, 256)), jnp.float32)
    want = engine.shard_spmm_batched(a, d, mesh=_mesh(2), bn=128, nt=1)
    fn = jax.jit(lambda a, d: engine.shard_spmm_batched_stream(
        a, d, mesh=_mesh(2), bn=128, nt=2))
    np.testing.assert_array_equal(np.asarray(fn(a, d)), np.asarray(want))
    got_b = engine.shard_spmm_batched_bucketed(a, d, mesh=_mesh(2), bn=128,
                                               nt=2)
    np.testing.assert_array_equal(np.asarray(got_b), np.asarray(want))


# ---------------------------------------------------------------------------
# spmspm: multi-output-column residency
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nt", [2, 4])
@pytest.mark.parametrize("C", [64, 52])  # incl. C % (nt*ct) != 0
def test_spmspm_nt_bit_identical(nt, C):
    left = random_dense_sparse(RNG, (48, 256), 0.1)
    right = random_dense_sparse(RNG, (256, C), 0.05)
    ak, av = spmspm_ops.dense_to_ell_rows(left)
    bk, bv = spmspm_ops.dense_to_ell_cols(right)
    want = spmspm_ops.spmspm(ak, av, bk, bv, rt=8, ct=8, nt=1,
                             interpret=True)
    got = spmspm_ops.spmspm(ak, av, bk, bv, rt=8, ct=8, nt=nt,
                            interpret=True)
    assert got.shape == (48, C)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(spmspm_ref(ak, av, bk, bv, 256)),
        atol=1e-4, rtol=1e-4)


@needs_mesh
def test_shard_spmspm_nt_matches_single_device():
    left = random_dense_sparse(RNG, (32, 128), 0.1)
    right = random_dense_sparse(RNG, (128, 40), 0.05)
    ak, av = spmspm_ops.dense_to_ell_rows(left)
    bk, bv = spmspm_ops.dense_to_ell_cols(right)
    want = spmspm_ops.spmspm(ak, av, bk, bv, rt=8, ct=8, nt=1,
                             interpret=True)
    got = engine.shard_spmspm(ak, av, bk, bv, mesh=_mesh(2), rt=8, ct=8,
                              nt=2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
