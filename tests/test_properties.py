"""Property-based tests (hypothesis) on the system's core invariants.

The whole module is skipped when ``hypothesis`` is not installed (the CI
container does not ship it); install it locally to run the property sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.formats import (INVALID_KEY, bcsr_from_dense, coo_from_dense,
                                csr_from_dense)
from repro.core.su import (intersect, intersect_dot, stream_densify,
                           topk_sparsify, union_add)
from repro.core.stencils import STENCILS, apply_reference
from repro.kernels.spmm import ops as spmm_ops
from repro.kernels.spmm.ref import spmm_ref
from repro.kernels.stencil import ops as stencil_ops
from repro.models.layers import chunked_attention
from repro.kernels.flash_attention.ref import attention_ref

SET = settings(max_examples=25, deadline=None)


def _pad_sorted(arr, cap):
    out = np.full(cap, INVALID_KEY, np.int32)
    out[: len(arr)] = np.sort(arr)
    return jnp.asarray(out)


@SET
@given(st.data())
def test_intersect_matches_numpy(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**32 - 1)))
    na = data.draw(st.integers(0, 60))
    nb = data.draw(st.integers(0, 60))
    a = rng.choice(200, size=na, replace=False).astype(np.int32)
    b = rng.choice(200, size=nb, replace=False).astype(np.int32)
    res = intersect(_pad_sorted(a, 64), _pad_sorted(b, 64))
    got = np.asarray(res.keys)[: int(res.count)]
    np.testing.assert_array_equal(got, np.intersect1d(a, b))


@SET
@given(st.data())
def test_union_add_is_dense_addition(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**32 - 1)))
    na = data.draw(st.integers(0, 48))
    nb = data.draw(st.integers(0, 48))
    D = 128
    ia = rng.choice(D, size=na, replace=False)
    ib = rng.choice(D, size=nb, replace=False)
    va = rng.standard_normal(na).astype(np.float32)
    vb = rng.standard_normal(nb).astype(np.float32)
    pa, pb = _pad_sorted(ia, 64), _pad_sorted(ib, 64)
    fa = np.zeros(64, np.float32)
    fa[: na] = va[np.argsort(ia)] if na else va
    fb = np.zeros(64, np.float32)
    fb[: nb] = vb[np.argsort(ib)] if nb else vb
    u = union_add(pa, jnp.asarray(fa), pb, jnp.asarray(fb))
    dense = np.zeros(D, np.float32)
    dense[ia] += va
    dense[ib] += vb
    got = np.asarray(stream_densify(u.keys, u.values, u.count, D))
    np.testing.assert_allclose(got, dense, atol=1e-5)


@SET
@given(st.data())
def test_intersect_dot_is_sparse_dot(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**32 - 1)))
    D = 96
    na = data.draw(st.integers(1, 40))
    nb = data.draw(st.integers(1, 40))
    ia = np.sort(rng.choice(D, size=na, replace=False))
    ib = np.sort(rng.choice(D, size=nb, replace=False))
    va = rng.standard_normal(64).astype(np.float32)
    vb = rng.standard_normal(64).astype(np.float32)
    got = intersect_dot(_pad_sorted(ia, 64), jnp.asarray(va),
                        _pad_sorted(ib, 64), jnp.asarray(vb))
    da = np.zeros(D); da[ia] = va[: na]
    db = np.zeros(D); db[ib] = vb[: nb]
    np.testing.assert_allclose(float(got), float(da @ db), rtol=1e-4,
                               atol=1e-4)


@SET
@given(st.data())
def test_topk_plus_error_reconstructs(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**32 - 1)))
    D = data.draw(st.integers(16, 256))
    k = data.draw(st.integers(1, D))
    g = jnp.asarray(rng.standard_normal(D), jnp.float32)
    keys, vals = topk_sparsify(g, k)
    dense = stream_densify(keys, vals, jnp.asarray(k), D)
    err = g - dense
    # top-k keeps the k largest magnitudes: error max <= kept min
    kept_min = float(jnp.abs(vals).min())
    assert float(jnp.abs(err).max()) <= kept_min + 1e-6
    np.testing.assert_allclose(np.asarray(dense + err), np.asarray(g),
                               atol=1e-6)


@SET
@given(st.data())
def test_sparse_format_roundtrips(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**32 - 1)))
    m = data.draw(st.sampled_from([8, 16, 32]))
    n = data.draw(st.sampled_from([8, 16, 64]))
    density = data.draw(st.floats(0.0, 0.6))
    dense = np.where(rng.random((m, n)) < density,
                     rng.standard_normal((m, n)), 0).astype(np.float32)
    np.testing.assert_allclose(np.asarray(csr_from_dense(dense).todense()), dense)
    np.testing.assert_allclose(np.asarray(bcsr_from_dense(dense, (8, 8)).todense()), dense)
    np.testing.assert_allclose(
        np.asarray(coo_from_dense(dense, capacity=dense.size).todense()), dense)


@SET
@given(st.data())
def test_spmm_kernel_matches_oracle(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**32 - 1)))
    gm = data.draw(st.integers(2, 6))
    gk = data.draw(st.integers(2, 6))
    density = data.draw(st.floats(0.05, 0.9))
    dense = np.where(rng.random((gm * 8, gk * 8)) < density,
                     rng.standard_normal((gm * 8, gk * 8)), 0).astype(np.float32)
    a = bcsr_from_dense(dense, (8, 8))
    b = jnp.asarray(rng.standard_normal((gk * 8, 128)), jnp.float32)
    got = spmm_ops.spmm(a, b, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(spmm_ref(a, b)),
                               atol=1e-4, rtol=1e-4)


@SET
@given(st.data())
def test_stencil_kernel_matches_oracle(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**32 - 1)))
    name = data.draw(st.sampled_from(["j2d5pt", "j2d9pt"]))
    spec = STENCILS[name]
    h = data.draw(st.integers(9, 40))
    w = data.draw(st.integers(9, 40))
    grid = jnp.asarray(rng.standard_normal(
        (h + 2 * spec.radius, w + 2 * spec.radius)), jnp.float32)
    got = stencil_ops.apply(grid, spec, tile=(8, 128), interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(apply_reference(spec, grid)),
                               atol=1e-4, rtol=1e-4)


@SET
@given(st.data())
def test_chunked_attention_matches_reference(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**32 - 1)))
    S = data.draw(st.sampled_from([17, 33, 64, 100]))
    hq = data.draw(st.sampled_from([2, 4]))
    hkv = data.draw(st.sampled_from([1, 2]))
    window = data.draw(st.sampled_from([None, 16]))
    chunk = data.draw(st.sampled_from([8, 32, 128]))
    q = jnp.asarray(rng.standard_normal((2, hq, S, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, hkv, S, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, hkv, S, 16)), jnp.float32)
    got = chunked_attention(q, k, v, causal=True, window=window, chunk=chunk)
    want = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


@SET
@given(st.data())
def test_wkv_chunked_matches_sequential(data):
    from repro.models.rwkv6 import rwkv_scan_ref, wkv_chunked
    rng = np.random.default_rng(data.draw(st.integers(0, 2**32 - 1)))
    T = data.draw(st.integers(3, 90))
    chunk = data.draw(st.sampled_from([4, 16, 64]))
    wmag = data.draw(st.floats(0.01, 1.0))
    B, nh, hd = 1, 2, 8
    r, k, v = (jnp.asarray(rng.standard_normal((B, T, nh, hd)), jnp.float32)
               for _ in range(3))
    w = jnp.maximum(-jnp.abs(jnp.asarray(
        rng.standard_normal((B, T, nh, hd)), jnp.float32)) * wmag, -1.0)
    u = jnp.asarray(rng.standard_normal((nh, hd)), jnp.float32) * 0.1
    y1, s1 = wkv_chunked(r, k, v, w, u, chunk=chunk)
    y2, s2 = rwkv_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-3,
                               rtol=2e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-3,
                               rtol=2e-3)


@SET
@given(st.data())
def test_ssd_chunked_matches_sequential(data):
    from repro.models.mamba2 import mamba_scan_ref, ssd_chunked
    rng = np.random.default_rng(data.draw(st.integers(0, 2**32 - 1)))
    T = data.draw(st.integers(3, 90))
    chunk = data.draw(st.sampled_from([4, 16, 64]))
    B, nh, hd, ns = 1, 2, 8, 4
    xh = jnp.asarray(rng.standard_normal((B, T, nh, hd)), jnp.float32)
    a = -jnp.abs(jnp.asarray(rng.standard_normal((B, T, nh)), jnp.float32))
    Bv = jnp.asarray(rng.standard_normal((B, T, ns)), jnp.float32)
    Cv = jnp.asarray(rng.standard_normal((B, T, ns)), jnp.float32)
    y1, h1 = ssd_chunked(xh, a, Bv, Cv, chunk=chunk)
    y2, h2 = mamba_scan_ref(xh, a, Bv, Cv)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-3,
                               rtol=2e-3)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-3,
                               rtol=2e-3)
