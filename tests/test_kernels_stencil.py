"""Pallas stencil kernel vs. pure-jnp oracle: shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.stencils import STENCILS
from repro.kernels.stencil import ops
from repro.kernels.stencil.ref import stencil_ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("name", ["j2d5pt", "j2d9pt", "j2d9pt-gol"])
@pytest.mark.parametrize("shape", [(16, 128), (24, 136), (64, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stencil_2d(name, shape, dtype):
    spec = STENCILS[name]
    r = spec.radius
    grid = jnp.asarray(RNG.standard_normal((shape[0] + 2 * r, shape[1] + 2 * r)),
                       dtype=dtype)
    got = ops.apply(grid, spec, tile=(8, 128), interpret=True)
    want = stencil_ref(grid, spec)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("name", ["j3d7pt", "j3d27pt"])
@pytest.mark.parametrize("shape", [(8, 8, 128), (10, 20, 130)])
def test_stencil_3d(name, shape):
    spec = STENCILS[name]
    r = spec.radius
    grid = jnp.asarray(
        RNG.standard_normal(tuple(s + 2 * r for s in shape)), dtype=jnp.float32)
    got = ops.apply(grid, spec, tile=(4, 8, 128), interpret=True)
    want = stencil_ref(grid, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_stencil_flops_accounting():
    spec = STENCILS["j3d27pt"]
    assert spec.points == 27
    assert ops.flops(spec, (10, 10, 10)) == 2 * 27 * 1000
