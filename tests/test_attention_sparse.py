"""Block-sparse attention on the BCSR stream walk.

Three layers of contract (see tests/README.md "Block-sparse attention
contract"):

1. **Pattern -> stream lowering** (``core.masks``): ``BlockMask.lower()``
   reconstructs exactly the tiles ``dense_mask()`` says are visible, every
   q-tile row is present, the stream is (row, col)-sorted, and bucket
   padding is dead entries at the last live coordinate.
2. **Kernel parity** (``kernels.flash_attention``): the sparse walk is
   ``array_equal``-identical to the masked dense grid for every pattern
   (both call the same ``_tile_update``), allclose to the jnp oracle, and
   bit-identical to the *pre-existing* causal/window kernel where the
   patterns coincide.
3. **System parity** (``engine`` / serving): the sharded wrapper matches
   single-device bit-for-bit (absolute-position refinement under nonzero
   ``q_offset``), serving with ``attn_mask=`` is token-identical between
   the sparse and dense-masked implementations on both dispatch backends,
   and recompiles stay bounded by (pattern signature x bucket).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import masks
from repro.core.masks import AttnMaskSpec, BlockMask
from repro.kernels import engine
from repro.kernels.flash_attention import kernel as fk
from repro.kernels.flash_attention import ops as fops
from repro.kernels.flash_attention.ref import attention_ref
from repro.models.config import ArchConfig
from repro.models import model as M
from repro.launch.serve import ServeLoop, ServeScheduler

KEY = jax.random.PRNGKey(0)


def _qkv(B=1, Hq=2, Hkv=2, Sq=64, Skv=64, D=16, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Skv, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Skv, D), dtype)
    return q, k, v


def _patterns(sq, skv, bq, bk):
    """The pattern zoo every parity test walks."""
    local = BlockMask.sliding_window(sq, skv, 3 * bk, bq=bq, bk=bk)
    return {
        "causal": BlockMask.causal(sq, skv, bq=bq, bk=bk),
        "window": BlockMask.sliding_window(sq, skv, 2 * bk, bq=bq, bk=bk),
        "strided": BlockMask.strided(sq, skv, 2, bq=bq, bk=bk),
        "global": BlockMask.global_cols(sq, skv, 1, bq=bq, bk=bk),
        "local|global": local | BlockMask.global_cols(sq, skv, 1,
                                                      bq=bq, bk=bk),
        "strided&causal": (BlockMask.strided(sq, skv, 2, bq=bq, bk=bk)
                           & BlockMask.causal(sq, skv, bq=bq, bk=bk)),
    }


PATTERN_NAMES = list(_patterns(64, 64, 16, 16))


# =========================================================== 1. lowering ==
@pytest.mark.parametrize("name", PATTERN_NAMES)
def test_lowering_matches_dense_oracle(name):
    """Rebuilding tile visibility from the lowered stream reproduces the
    tile_kinds map, and expanding the stream tile-by-tile reproduces the
    dense boolean oracle."""
    m = _patterns(64, 96, 16, 16)[name]
    s = m.lower(bucket=False)
    # sorted by (row, col), every row present
    order = s.rows * (m.n_kv_tiles + 1) + s.cols
    assert (np.diff(order) >= 0).all()
    assert set(s.rows.tolist()) == set(range(m.n_q_tiles))
    # live entries reconstruct tile_kinds exactly
    rebuilt = np.full_like(m.tile_kinds, masks.KIND_DEAD)
    live = s.kinds >= 0
    rebuilt[s.rows[live], s.cols[live]] = s.kinds[live]
    np.testing.assert_array_equal(rebuilt, np.where(
        m.tile_kinds >= 0, m.tile_kinds, masks.KIND_DEAD))
    # dense expansion of the stream == the oracle
    dense = np.zeros((m.n_q_tiles * m.bq, m.n_kv_tiles * m.bk), bool)
    q = np.arange(dense.shape[0])[:, None]
    kpos = np.arange(dense.shape[1])[None, :]
    for r, c, kind in zip(s.rows, s.cols, s.kinds):
        if kind < 0:
            continue
        tile = np.ones((m.bq, m.bk), bool)
        qq = q[r * m.bq:(r + 1) * m.bq, :1] + m.q_offset
        kk = kpos[:1, c * m.bk:(c + 1) * m.bk]
        if kind & masks.KIND_CAUSAL:
            tile &= qq >= kk
        if kind & masks.KIND_WINDOW:
            tile &= (qq - kk) < m.window
        dense[r * m.bq:(r + 1) * m.bq, c * m.bk:(c + 1) * m.bk] = tile
    np.testing.assert_array_equal(dense[:m.sq, :m.skv], m.dense_mask())


def test_lowering_bucket_padding():
    m = BlockMask.sliding_window(64, 64, 32, bq=16, bk=16)
    raw = m.lower(bucket=False)
    b = m.lower(bucket=True)
    assert b.capacity == masks.next_pow2(raw.capacity)
    assert b.nnzb == raw.nnzb
    # pads repeat the last live coordinate with KIND_DEAD
    assert (b.kinds[raw.capacity:] == masks.KIND_DEAD).all()
    assert (b.rows[raw.capacity:] == raw.rows[-1]).all()
    assert (b.cols[raw.capacity:] == raw.cols[-1]).all()


def test_compose_matches_elementwise():
    """& / | compose like the dense boolean masks they lower to."""
    sq = skv = 64
    a = BlockMask.sliding_window(sq, skv, 32, bq=16, bk=16)
    b = BlockMask.strided(sq, skv, 2, bq=16, bk=16)
    g = BlockMask.global_cols(sq, skv, 1, bq=16, bk=16)
    np.testing.assert_array_equal((a & b).dense_mask(),
                                  a.dense_mask() & b.dense_mask())
    np.testing.assert_array_equal((a | g).dense_mask(),
                                  a.dense_mask() | g.dense_mask())
    # union keeps the laxer refinement; intersection accumulates bits
    assert (a | g).nnzb >= max(a.nnzb, g.nnzb)
    assert (a & b).nnzb <= min(a.nnzb, b.nnzb)
    d = a.density()
    assert d["nnzb"] < d["dense_tiles"]          # the walk actually shrank
    assert 0.0 < d["block_fill"] < 1.0


def test_compose_window_mismatch_raises():
    a = BlockMask.sliding_window(64, 64, 32, bq=16, bk=16)
    b = BlockMask.sliding_window(64, 64, 16, bq=16, bk=16)
    with pytest.raises(ValueError):
        _ = a & b


def test_from_dense_rounds_up_to_tiles():
    """Arbitrary per-row block lists: sub-tile structure rounds UP, the
    oracle reflects the rounded (block-granular) semantics."""
    rng = np.random.default_rng(0)
    dense = rng.random((52, 40)) < 0.2
    m = BlockMask.from_dense(dense, bq=16, bk=16)
    got = m.dense_mask()
    assert got[dense].all()                      # nothing visible was lost
    blk = got.reshape(-1)                        # block-granular: any -> all
    tiles = m.tile_kinds >= 0
    for r in range(m.n_q_tiles):
        for c in range(m.n_kv_tiles):
            sub = dense[r * 16:(r + 1) * 16, c * 16:(c + 1) * 16]
            assert tiles[r, c] == sub.any()
    del blk


# ====================================================== 2. kernel parity ==
@pytest.mark.parametrize("name", PATTERN_NAMES)
def test_sparse_equals_masked_dense(name):
    """The stream walk is bit-identical to the dense kind-map grid (same
    _tile_update, same visit order per row) and allclose to the oracle."""
    bq = bk = 16
    m = _patterns(64, 96, bq, bk)[name]
    q, k, v = _qkv(B=2, Hq=2, Hkv=2, Sq=64, Skv=96)
    s = m.lower(bucket=True)
    sparse = fk.flash_attention_sparse(
        q, k, v, s.rows, s.cols, s.kinds, skv=96, window=m.window,
        bq=bq, bk=bk, interpret=True)
    dense = fk.flash_attention_masked(
        q, k, v, m.tile_kinds, skv=96, window=m.window, interpret=True)
    np.testing.assert_array_equal(np.asarray(sparse), np.asarray(dense))
    ref = attention_ref(q, k, v, mask=m)
    np.testing.assert_allclose(np.asarray(sparse), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("window", [None, 24])
def test_sparse_equals_preexisting_kernel(window):
    """Where the pattern is plain causal / sliding-window, the sparse walk
    reproduces the untouched pre-existing flash kernel bit-for-bit."""
    bq = bk = 16
    q, k, v = _qkv(Sq=64, Skv=64)
    m = BlockMask.full(64, 64, bq=bq, bk=bk, causal=True, window=window)
    s = m.lower(bucket=True)
    sparse = fk.flash_attention_sparse(
        q, k, v, s.rows, s.cols, s.kinds, skv=64, window=window,
        bq=bq, bk=bk, interpret=True)
    plain = fk.flash_attention(q, k, v, causal=True, window=window,
                               bq=bq, bk=bk, interpret=True)
    np.testing.assert_array_equal(np.asarray(sparse), np.asarray(plain))


def test_bucketed_stream_is_noop():
    """Bucket padding (dead entries) changes nothing in the output."""
    bq = bk = 16
    m = BlockMask.sliding_window(64, 64, 32, bq=bq, bk=bk)
    q, k, v = _qkv()
    outs = []
    for bucket in (False, True):
        s = m.lower(bucket=bucket)
        outs.append(np.asarray(fk.flash_attention_sparse(
            q, k, v, s.rows, s.cols, s.kinds, skv=64, window=m.window,
            bq=bq, bk=bk, interpret=True)))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_ragged_and_gqa_via_ops():
    """ops.attention(mask=) pads ragged S to tiles; GQA heads share KV."""
    Sq = Skv = 52                                # ragged: not a tile multiple
    q, k, v = _qkv(B=2, Hq=4, Hkv=2, Sq=Sq, Skv=Skv)
    m = BlockMask.sliding_window(Sq, Skv, 24, bq=16, bk=16)
    sparse = fops.attention(q, k, v, mask=m, mask_impl="sparse",
                            interpret=True)
    dense = fops.attention(q, k, v, mask=m, mask_impl="dense",
                           interpret=True)
    np.testing.assert_array_equal(np.asarray(sparse), np.asarray(dense))
    ref = attention_ref(q, k, v, mask=m)
    np.testing.assert_allclose(np.asarray(sparse), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)


def test_q_offset_shard_equals_full_slice():
    """A row-shard's sub-mask (nonzero q_offset) reproduces its slice of the
    full computation exactly -- refinements compare absolute positions."""
    bq = bk = 16
    Sq = Skv = 64
    q, k, v = _qkv(Sq=Sq, Skv=Skv)
    m = BlockMask.sliding_window(Sq, Skv, 32, bq=bq, bk=bk)
    s_full = m.lower(bucket=True)
    full = np.asarray(fk.flash_attention_sparse(
        q, k, v, s_full.rows, s_full.cols, s_full.kinds, skv=Skv,
        window=m.window, bq=bq, bk=bk, interpret=True))
    subs = m.shard_rows(2)
    assert subs[1].q_offset == Sq // 2
    s1 = subs[1].lower(bucket=True)
    part = np.asarray(fk.flash_attention_sparse(
        q[:, :, Sq // 2:], k, v, s1.rows, s1.cols, s1.kinds, skv=Skv,
        window=m.window, bq=bq, bk=bk,
        q_offset=subs[1].q_offset, interpret=True))
    np.testing.assert_array_equal(part, full[:, :, Sq // 2:])


@pytest.mark.parametrize("name", ["window", "causal", "local|global"])
def test_sharded_wrapper_matches_single_device(name):
    """engine.shard_attention_sparse on the 4-virtual-device CPU mesh is
    bit-identical to the unsharded walk (per-shard streams at a common
    bucket, per-shard absolute q_offset)."""
    if jax.device_count() < 2:
        pytest.skip("needs the multi-device CPU topology (conftest)")
    bq = bk = 8
    Sq = Skv = 64
    m = _patterns(Sq, Skv, bq, bk)[name]
    q, k, v = _qkv(B=1, Hq=2, Hkv=2, Sq=Sq, Skv=Skv, D=16)
    single = fops.attention(q, k, v, mask=m, mask_impl="sparse",
                            interpret=True)
    sharded = engine.shard_attention_sparse(q, k, v, m, interpret=True)
    np.testing.assert_array_equal(np.asarray(sharded), np.asarray(single))


# ================================================= 3. fallback discipline ==
def test_fallback_counter_and_error_knob():
    q, k, v = _qkv(Sq=16, Skv=16)
    fops.reset_fallbacks()
    # explicit reference routing is counted
    fops.attention(q, k, v, use_kernel=False)
    assert fops.fallback_count() == 1
    assert fops.fallback_reasons() == {"use_kernel=False": 1}
    # shape-forced fallback: non-causal ragged KV needs pad masking
    q2, k2, v2 = _qkv(Sq=16, Skv=13)
    fops.attention(q2, k2, v2, causal=False, bq=8, bk=8)
    assert fops.fallback_count() == 2
    assert fops.fallback_reasons()["noncausal_kv_pad"] == 1
    # fallback="error" turns both into hard failures
    with pytest.raises(RuntimeError, match="fallback='error'"):
        fops.attention(q, k, v, use_kernel=False, fallback="error")
    with pytest.raises(RuntimeError, match="fallback='error'"):
        fops.attention(q2, k2, v2, causal=False, bq=8, bk=8,
                       fallback="error")
    # the masked-kernel paths never touch the reference
    before = fops.fallback_count()
    m = BlockMask.causal(16, 16, bq=8, bk=8)
    fops.attention(q, k, v, mask=m, interpret=True, fallback="error")
    assert fops.fallback_count() == before
    fops.reset_fallbacks()


# ==================================================== 4. serving parity ==
TINY_LOCAL = ArchConfig(
    name="tiny-local", family="dense", d_model=32, n_heads=2, n_kv_heads=1,
    d_ff=48, vocab_size=64,
    block_unit=("attn_local", "attn_local", "attn_global"), n_repeats=2,
    head_dim=16, local_window=8, policy="f32")

TINY_MOE = ArchConfig(
    name="tiny-moe-mask", family="moe", d_model=32, n_heads=2, n_kv_heads=1,
    d_ff=48, vocab_size=64, block_unit=("attn_local", "attn+moe"),
    n_repeats=2, head_dim=16, local_window=8, n_experts=4, top_k=1,
    capacity_factor=1.0, moe_shared_expert=True, policy="f32")


@pytest.mark.serve
def test_serveloop_sparse_vs_dense_token_identical():
    """Sliding-window prefill through the sparse walk generates exactly the
    tokens of the dense-masked parity baseline (gemma3-style local stack)."""
    params = M.init_params(KEY, TINY_LOCAL)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                 TINY_LOCAL.vocab_size)
    toks = {}
    for impl in ("sparse", "dense"):
        spec = AttnMaskSpec(local=True, impl=impl, bq=8, bk=8)
        loop = ServeLoop(params, TINY_LOCAL, max_seq=24, attn_mask=spec)
        toks[impl] = loop.run(prompts, 8)
    np.testing.assert_array_equal(toks["sparse"], toks["dense"])


@pytest.mark.serve
@pytest.mark.parametrize("dispatch", ["gather", "bcsr"])
def test_scheduler_attn_mask_both_backends(dispatch):
    """ServeScheduler with attn_mask= (local + long-context local_global
    pattern) is token-identical between the sparse and dense-masked
    implementations on both MoE dispatch backends, and the masked-path
    recompile count stays bounded by (pattern signature x bucket)."""
    params = M.init_params(KEY, TINY_MOE)
    rng = np.random.default_rng(0)
    reqs = [(rng.integers(0, TINY_MOE.vocab_size, int(rng.integers(6, 12))),
             int(rng.integers(3, 6))) for _ in range(4)]

    def run(impl):
        spec = AttnMaskSpec(local=True, pattern="local_global", window=8,
                            impl=impl, bq=8, bk=8)
        sched = ServeScheduler(params, TINY_MOE, max_seq=24, max_slots=2,
                               dispatch=dispatch, attn_mask=spec)
        for prompt, gen in reqs:
            sched.submit(prompt, gen)
        return sched.run()

    fops.reset_mask_signatures()
    sparse = run("sparse")
    n_sigs = len([s for s in fops.mask_signatures() if s[0] == "sparse"])
    dense = run("dense")
    assert set(sparse) == set(dense)
    for uid in sparse:
        np.testing.assert_array_equal(sparse[uid], dense[uid])
    # recompile bound: distinct prompt lengths all bucket to a handful of
    # (geometry x capacity) keys -- never one signature per request
    assert 0 < n_sigs <= 2 * len({p.size for p, _ in reqs})


@pytest.mark.serve
def test_serveloop_surfaces_fallback_counter():
    """mask_impl='ref' routes through the counted oracle; the count shows
    up in summary()['timing']."""
    params = M.init_params(KEY, TINY_LOCAL)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0,
                                 TINY_LOCAL.vocab_size)
    spec = AttnMaskSpec(local=True, impl="ref", bq=8, bk=8)
    loop = ServeLoop(params, TINY_LOCAL, max_seq=20, attn_mask=spec)
    loop.run(prompts, 4)
    s = loop.summary()
    assert s["timing"]["attention_ref_fallbacks"] > 0
