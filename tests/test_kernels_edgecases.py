"""Edge-case kernel parity (interpret mode): degenerate sparsity patterns,
non-aligned shapes, and dtype accumulation parity vs. the jnp oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.formats import (BCSR, batched_bcsr_from_dense, bcsr_from_dense,
                                random_dense_sparse)
from repro.kernels import tuning
from repro.kernels.spmm import ops as spmm_ops
from repro.kernels.spmm.ref import spmm_ref
from repro.kernels.spmspm import ops as spmspm_ops
from repro.kernels.spmspm.ref import spmspm_ref

RNG = np.random.default_rng(3)


# ---------------------------------------------------------------------------
# SpMM degenerate patterns
# ---------------------------------------------------------------------------

def test_spmm_all_zero_matrix():
    """Every block-row empty: pad_empty_rows must fabricate the full stream."""
    a = bcsr_from_dense(np.zeros((32, 32), np.float32), (8, 8))
    assert a.nnzb == 0
    b = jnp.asarray(RNG.standard_normal((32, 128)), jnp.float32)
    got = spmm_ops.spmm(a, b, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.zeros((32, 128)))


def test_spmm_single_nonzero_block():
    dense = np.zeros((64, 64), np.float32)
    dense[16:24, 40:48] = RNG.standard_normal((8, 8))
    a = bcsr_from_dense(dense, (8, 8))
    assert a.nnzb == 1
    b = jnp.asarray(RNG.standard_normal((64, 128)), jnp.float32)
    got = spmm_ops.spmm(a, b, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(spmm_ref(a, b)),
                               atol=1e-4, rtol=1e-4)


def test_spmm_trailing_rows_empty():
    """Empty block-rows at the *end* of the matrix (pad ordering edge)."""
    dense = np.zeros((64, 64), np.float32)
    dense[:8] = RNG.standard_normal((8, 64))
    a = bcsr_from_dense(dense, (8, 8))
    b = jnp.asarray(RNG.standard_normal((64, 128)), jnp.float32)
    got = spmm_ops.spmm(a, b, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(spmm_ref(a, b)),
                               atol=1e-4, rtol=1e-4)


def test_spmm_explicit_bn_must_be_lane_aligned():
    """Explicit bn overrides are honored exactly or rejected loudly: the
    old silent min(bn, max(128, n)) clamp turned bn=100 into an unaligned
    tile and rewrote bn=256 under small N."""
    a = bcsr_from_dense(random_dense_sparse(RNG, (32, 32), 0.4), (8, 8))
    b = jnp.asarray(RNG.standard_normal((32, 64)), jnp.float32)
    for bad in (100, 64, -128, 0):
        with pytest.raises(ValueError, match="multiple of the 128-lane"):
            spmm_ops.spmm(a, b, bn=bad, interpret=True)
    ab = batched_bcsr_from_dense(
        np.stack([random_dense_sparse(RNG, (32, 32), 0.4)] * 2), (8, 8))
    with pytest.raises(ValueError, match="multiple of the 128-lane"):
        spmm_ops.spmm_batched(ab, b, bn=100, interpret=True)
    # an aligned override wider than N is legal: pad-and-strip, same bits
    got = spmm_ops.spmm(a, b, bn=256, interpret=True)
    want = spmm_ops.spmm(a, b, bn=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("N", [1, 7, 129, 200])
def test_spmm_n_not_multiple_of_default_bn(N):
    """N smaller / larger than (and coprime to) the tuned bn."""
    a = bcsr_from_dense(random_dense_sparse(RNG, (32, 32), 0.4), (8, 8))
    b = jnp.asarray(RNG.standard_normal((32, N)), jnp.float32)
    got = spmm_ops.spmm(a, b, interpret=True)  # bn from the autotune table
    assert got.shape == (32, N)
    np.testing.assert_allclose(np.asarray(got), np.asarray(spmm_ref(a, b)),
                               atol=1e-4, rtol=1e-4)


def test_spmm_fp32_vs_bf16_accumulation():
    """bf16 inputs accumulate in fp32 on the MXU path
    (preferred_element_type): parity with the fp32 oracle within bf16
    rounding of the *inputs* only."""
    a_dense = random_dense_sparse(RNG, (64, 64), 0.3)
    a32 = bcsr_from_dense(a_dense, (8, 8))
    b32 = jnp.asarray(RNG.standard_normal((64, 128)), jnp.float32)
    a16 = BCSR(indptr=a32.indptr, block_rows=a32.block_rows,
               block_cols=a32.block_cols,
               blocks=a32.blocks.astype(jnp.bfloat16),
               shape=a32.shape, block=a32.block)
    got16 = spmm_ops.spmm(a16, b32.astype(jnp.bfloat16), interpret=True)
    # Oracle on the bf16-rounded inputs: the only divergence allowed is
    # input rounding, NOT accumulation error.
    ref16 = spmm_ref(
        BCSR(indptr=a16.indptr, block_rows=a16.block_rows,
             block_cols=a16.block_cols,
             blocks=a16.blocks.astype(jnp.float32),
             shape=a16.shape, block=a16.block),
        b32.astype(jnp.bfloat16).astype(jnp.float32))
    assert got16.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got16), np.asarray(ref16),
                               atol=1e-3, rtol=1e-3)


def test_spmm_batched_union_pattern():
    """Batch elements with disjoint patterns share one union stream; each
    element must still equal its own per-matrix product."""
    d0 = np.zeros((32, 32), np.float32)
    d0[:8, :8] = RNG.standard_normal((8, 8))
    d1 = np.zeros((32, 32), np.float32)
    d1[24:, 24:] = RNG.standard_normal((8, 8))
    a = batched_bcsr_from_dense(np.stack([d0, d1]), (8, 8))
    assert a.nnzb == 2  # union of two disjoint single-block patterns
    d = jnp.asarray(RNG.standard_normal((2, 32, 96)), jnp.float32)
    got = spmm_ops.spmm_batched(a, d, interpret=True)
    for i, m in enumerate([d0, d1]):
        want = spmm_ref(bcsr_from_dense(m, (8, 8)), d[i])
        np.testing.assert_allclose(np.asarray(got[i]), np.asarray(want),
                                   atol=1e-4, rtol=1e-4)


def test_batched_container_roundtrip():
    stack = np.stack([random_dense_sparse(RNG, (32, 32), 0.25)
                      for _ in range(3)])
    a = batched_bcsr_from_dense(stack, (8, 8))
    np.testing.assert_allclose(np.asarray(a.todense()), stack)


# ---------------------------------------------------------------------------
# SpMSpM degenerate patterns
# ---------------------------------------------------------------------------

def test_spmspm_single_match():
    """Exactly one key match across the whole product."""
    A = np.zeros((8, 64), np.float32)
    B = np.zeros((64, 8), np.float32)
    A[3, 17] = 2.0
    B[17, 5] = 3.0
    ak, av = spmspm_ops.dense_to_ell_rows(A)
    bk, bv = spmspm_ops.dense_to_ell_cols(B)
    got = np.asarray(spmspm_ops.spmspm(ak, av, bk, bv, interpret=True))
    want = np.zeros((8, 8), np.float32)
    want[3, 5] = 6.0
    np.testing.assert_allclose(got, want)


def test_spmspm_no_matches():
    """Disjoint key sets: all-pairs comparison must produce exact zeros."""
    A = np.zeros((8, 64), np.float32)
    B = np.zeros((64, 8), np.float32)
    A[:, :32] = RNG.standard_normal((8, 32))
    B[32:, :] = RNG.standard_normal((32, 8))
    ak, av = spmspm_ops.dense_to_ell_rows(A)
    bk, bv = spmspm_ops.dense_to_ell_cols(B)
    got = np.asarray(spmspm_ops.spmspm(ak, av, bk, bv, interpret=True))
    np.testing.assert_array_equal(got, np.zeros((8, 8)))


def test_spmspm_r_c_not_tile_multiples():
    """R/C coprime to the tuned (rt, ct): ops pads with INVALID streams."""
    A = random_dense_sparse(RNG, (13, 64), 0.3)
    B = random_dense_sparse(RNG, (64, 11), 0.2)
    ak, av = spmspm_ops.dense_to_ell_rows(A)
    bk, bv = spmspm_ops.dense_to_ell_cols(B)
    got = spmspm_ops.spmspm(ak, av, bk, bv, interpret=True)
    assert got.shape == (13, 11)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(spmspm_ref(ak, av, bk, bv, 64)),
                               atol=1e-4, rtol=1e-4)


def test_spmspm_fp32_vs_bf16_values():
    """bf16 value streams accumulate in fp32 inside the kernel."""
    A = random_dense_sparse(RNG, (16, 64), 0.3)
    B = random_dense_sparse(RNG, (64, 16), 0.2)
    ak, av = spmspm_ops.dense_to_ell_rows(A)
    bk, bv = spmspm_ops.dense_to_ell_cols(B)
    got16 = spmspm_ops.spmspm(ak, jnp.asarray(av).astype(jnp.bfloat16),
                              bk, jnp.asarray(bv).astype(jnp.bfloat16),
                              rt=8, ct=8, interpret=True)
    ref16 = spmspm_ref(ak, np.asarray(jnp.asarray(av).astype(jnp.bfloat16)
                                      .astype(jnp.float32)),
                       bk, np.asarray(jnp.asarray(bv).astype(jnp.bfloat16)
                                      .astype(jnp.float32)), 64)
    assert got16.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got16), np.asarray(ref16),
                               atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# Autotune table sanity
# ---------------------------------------------------------------------------

def test_tuning_alignment_invariants():
    for n in (1, 100, 128, 1000, 4096):
        for dt in (jnp.float32, jnp.bfloat16):
            bn = tuning.spmm_bn(n, dt)
            assert bn % tuning.LANE == 0 and bn >= tuning.LANE
    rt, ct = tuning.spmspm_tiles(13, 11, 32, 32)
    assert rt % tuning.SUBLANE == 0 and ct % tuning.SUBLANE == 0
    t2 = tuning.stencil_tile((40, 40))
    assert len(t2) == 2 and t2[-1] % tuning.LANE == 0
    t3 = tuning.stencil_tile((10, 10, 200))
    assert len(t3) == 3 and t3[-1] % tuning.LANE == 0


def test_tuning_lookup_front_door():
    assert set(tuning.lookup("spmm", n=256)) == {"bn", "nt"}
    assert set(tuning.lookup("spmspm", r=16, c=16, la=8, lb=8)) == {
        "rt", "ct", "nt"}
    assert set(tuning.lookup("stencil", interior=(32, 200))) == {"tile"}
    assert set(tuning.lookup("wkv", t=256)) == {"chunk"}
    assert set(tuning.lookup("flash", sq=256, skv=256, d=64)) == {"bq", "bk"}
    with pytest.raises(KeyError):
        tuning.lookup("nope")


def test_tuning_register_override():
    tuning.register("spmm", jnp.float32, {"bn": 256}, platform="cpu")
    try:
        assert tuning.spmm_bn(1024, jnp.float32) == 256
    finally:
        tuning.register("spmm", jnp.float32, {"bn": 128}, platform="cpu")
    assert tuning.spmm_bn(1024, jnp.float32) == 128
