"""BCSR SpMM kernel vs. oracle: density/shape/block/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.formats import bcsr_from_dense, random_dense_sparse, banded_sparse
from repro.kernels.spmm import ops
from repro.kernels.spmm.ref import spmm_ref, spmm_gather_baseline

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("density", [0.05, 0.3, 1.0])
@pytest.mark.parametrize("block", [(8, 8), (8, 16)])
@pytest.mark.parametrize("mkn", [(64, 64, 128), (128, 96, 256)])
def test_spmm_random(density, block, mkn):
    m, k, n = mkn
    a_dense = random_dense_sparse(RNG, (m, k), density)
    a = bcsr_from_dense(a_dense, block)
    b = jnp.asarray(RNG.standard_normal((k, n)), jnp.float32)
    got = ops.spmm(a, b, bn=128, interpret=True)
    want = spmm_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_spmm_empty_rows():
    m, k, n = 64, 64, 128
    a_dense = np.zeros((m, k), np.float32)
    a_dense[9, :16] = 1.0  # only one block-row non-empty
    a = bcsr_from_dense(a_dense, (8, 8))
    b = jnp.asarray(RNG.standard_normal((k, n)), jnp.float32)
    got = ops.spmm(a, b, interpret=True)
    want = spmm_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_spmm_banded_bf16_inputs():
    m, k, n = 64, 64, 128
    a_dense = banded_sparse(RNG, (m, k), bandwidth=6)
    a = bcsr_from_dense(a_dense.astype(np.float32), (8, 8))
    a = type(a)(indptr=a.indptr, block_rows=a.block_rows, block_cols=a.block_cols,
                blocks=a.blocks.astype(jnp.bfloat16), shape=a.shape, block=a.block)
    b = jnp.asarray(RNG.standard_normal((k, n)), jnp.bfloat16)
    got = ops.spmm(a, b, interpret=True)
    want = spmm_ref(a, b.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=0.5, rtol=5e-2)


def test_gather_baseline_matches_ref():
    a_dense = random_dense_sparse(RNG, (64, 64), 0.2)
    a = bcsr_from_dense(a_dense, (8, 8))
    b = jnp.asarray(RNG.standard_normal((64, 128)), jnp.float32)
    np.testing.assert_allclose(np.asarray(spmm_gather_baseline(a, b)),
                               np.asarray(spmm_ref(a, b)), atol=1e-4)


def test_spmm_n_not_multiple_of_bn():
    a_dense = random_dense_sparse(RNG, (32, 32), 0.4)
    a = bcsr_from_dense(a_dense, (8, 8))
    b = jnp.asarray(RNG.standard_normal((32, 100)), jnp.float32)
    got = ops.spmm(a, b, bn=128, interpret=True)
    assert got.shape == (32, 100)
    np.testing.assert_allclose(np.asarray(got), np.asarray(spmm_ref(a, b)),
                               atol=1e-4)
