"""Sharded, atomic, elastic checkpointing.

* Each host writes its param/opt shards as ``.npz`` per pytree-chunk under
  ``step_<N>.tmp``; a final atomic rename + ``LATEST`` pointer update commits
  the step (a torn write can never be mistaken for a complete checkpoint).
* Restore is **elastic**: arrays are saved unsharded-logical (global view via
  ``jax.device_get``) with the pytree structure, so they can be re-put onto
  any mesh/sharding -- restoring a 256-chip checkpoint onto a different mesh
  shape re-shards transparently (tested in tests/test_checkpoint.py).
* For multi-host scale the same layout shards the *write* (each host dumps
  only addressable shards); this single-host build writes the global view,
  and DESIGN.md S5 records the delta.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _pack_leaf(arr: np.ndarray) -> Tuple[np.ndarray, Optional[str]]:
    """``np.savez`` silently degrades extension dtypes (ml_dtypes bf16 /
    fp8 -- numpy kind ``V``) to raw void records that load back as ``|V2``
    garbage.  Byte-view those to uint8 and return the true dtype name so
    :func:`_unpack_leaf` can view them back losslessly."""
    if np.dtype(arr.dtype).kind != "V":
        return arr, None
    raw = np.frombuffer(
        np.ascontiguousarray(arr).tobytes(), np.uint8).reshape(
            arr.shape[:-1] + (-1,) if arr.ndim else (-1,))
    return raw, np.dtype(arr.dtype).name


def _unpack_leaf(raw: np.ndarray, dtype_name: Optional[str],
                 shape) -> np.ndarray:
    if dtype_name is None:
        return raw
    # ml_dtypes (imported via jax) registers the names with np.dtype
    return raw.reshape(-1).view(np.dtype(dtype_name)).reshape(shape)


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # ----------------------------------------------------------- writing --

    def save(self, step: int, state: Any, metadata: Optional[dict] = None):
        """Atomic save: write to step_<N>.tmp, fsync, rename, repoint LATEST."""
        tmp = self.dir / f"step_{step}.tmp"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        leaves, treedef = _flatten(state)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
        packed, dtypes, shapes = [], {}, {}
        for i, l in enumerate(host_leaves):
            raw, name = _pack_leaf(l)
            packed.append(raw)
            if name is not None:
                dtypes[str(i)] = name
                shapes[str(i)] = list(l.shape)
        np.savez(tmp / "leaves.npz",
                 **{f"leaf_{i}": l for i, l in enumerate(packed)})
        (tmp / "meta.json").write_text(json.dumps({
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(host_leaves),
            "leaf_dtypes": dtypes,     # only the byte-packed (kind-V) leaves
            "leaf_shapes": shapes,
            "metadata": metadata or {},
        }))
        os.replace(tmp, final)                      # atomic on POSIX
        latest_tmp = self.dir / "LATEST.tmp"
        latest_tmp.write_text(str(step))
        os.replace(latest_tmp, self.dir / "LATEST")
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ----------------------------------------------------------- reading --

    def all_steps(self):
        return [int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                if not p.name.endswith(".tmp")]

    def latest_step(self) -> Optional[int]:
        f = self.dir / "LATEST"
        if not f.exists():
            steps = self.all_steps()
            return max(steps) if steps else None
        step = int(f.read_text().strip())
        # tolerate a crash between rename and pointer update
        if not (self.dir / f"step_{step}").exists():
            steps = self.all_steps()
            return max(steps) if steps else None
        return step

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, int]:
        """Restore into the structure of ``like``; ``shardings`` (pytree of
        NamedSharding or None) re-shards elastically onto the current mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.dir}")
        d = self.dir / f"step_{step}"
        blob = np.load(d / "leaves.npz")
        meta = json.loads((d / "meta.json").read_text())
        leaves, treedef = _flatten(like)
        assert meta["n_leaves"] == len(leaves), \
            f"checkpoint has {meta['n_leaves']} leaves, model has {len(leaves)}"
        dtypes = meta.get("leaf_dtypes", {})
        shapes = meta.get("leaf_shapes", {})
        host = [_unpack_leaf(blob[f"leaf_{i}"], dtypes.get(str(i)),
                             tuple(shapes.get(str(i), ())))
                for i in range(len(leaves))]
        for h, l in zip(host, leaves):
            assert h.shape == l.shape, (h.shape, l.shape)
        if shardings is not None:
            sh_leaves = jax.tree.flatten(shardings)[0]
            out = [jax.device_put(h, s) if s is not None else jax.device_put(h)
                   for h, s in zip(host, sh_leaves)]
        else:
            out = [jax.device_put(h) for h in host]
        return jax.tree.unflatten(treedef, out), step

    def metadata(self, step: int) -> dict:
        return json.loads(
            (self.dir / f"step_{step}" / "meta.json").read_text())["metadata"]
