"""Trace-time activation-sharding hints.

Step builders (repro.launch.steps) set these before tracing; model code reads
them through ``repro.parallel.sharding.constrain``. They are PartitionSpecs
(not shardings), resolved against the ambient mesh by pjit. ``None`` = leave
placement to the SPMD partitioner.
"""
from __future__ import annotations

import contextlib
from typing import Optional

from jax.sharding import PartitionSpec as P

ACT_SPEC: Optional[P] = None   # residual stream (B, S, d)
MOE_SPEC: Optional[P] = None   # dispatched expert tiles (E, G, Cg, d)
LOGIT_SPEC: Optional[P] = None  # logits (B, S, V)
MOE_GROUPS: Optional[int] = None  # dispatch groups (= data shards)
MOE_COMBINE_SPEC: Optional[P] = None  # post-expert tiles (G, E*Cg, d)
MOE_IMPL: str = "pjit"                # "pjit" | "shard_map" (SPerf-C)
MESH = None                           # concrete mesh for shard_map paths


@contextlib.contextmanager
def activation_specs(act: Optional[P] = None, moe: Optional[P] = None,
                     logit: Optional[P] = None, moe_groups: Optional[int] = None,
                     moe_combine: Optional[P] = None, moe_impl: str = "pjit",
                     mesh=None):
    global ACT_SPEC, MOE_SPEC, LOGIT_SPEC, MOE_GROUPS, MOE_COMBINE_SPEC,         MOE_IMPL, MESH
    prev = (ACT_SPEC, MOE_SPEC, LOGIT_SPEC, MOE_GROUPS, MOE_COMBINE_SPEC,
            MOE_IMPL, MESH)
    ACT_SPEC, MOE_SPEC, LOGIT_SPEC, MOE_GROUPS, MOE_COMBINE_SPEC,         MOE_IMPL, MESH = (act, moe, logit, moe_groups, moe_combine,
                          moe_impl, mesh)
    try:
        yield
    finally:
        (ACT_SPEC, MOE_SPEC, LOGIT_SPEC, MOE_GROUPS, MOE_COMBINE_SPEC,
         MOE_IMPL, MESH) = prev
