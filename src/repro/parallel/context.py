"""Trace-time activation-sharding hints.

Step builders (repro.launch.steps) set these before tracing; model code reads
them through ``repro.parallel.sharding.constrain``. They are PartitionSpecs
(not shardings), resolved against the ambient mesh by pjit. ``None`` = leave
placement to the SPMD partitioner.
"""
from __future__ import annotations

import contextlib
from typing import Optional

from jax.sharding import PartitionSpec as P

ACT_SPEC: Optional[P] = None   # residual stream (B, S, d)
MOE_SPEC: Optional[P] = None   # dispatched expert tiles (E, B, C, d)
LOGIT_SPEC: Optional[P] = None  # logits (B, S, V)
MOE_GROUPS: Optional[int] = None  # dispatch row groups (= data shards);
#   routing is per batch row, so this only *validates* that the dispatch
#   buffer's B dim can align with the data axes (see models.moe.apply_moe)
MOE_COMBINE_SPEC: Optional[P] = None  # post-expert tiles (B, E*C, d)
MOE_IMPL: str = "pjit"                # "pjit" | "shard_map" (SPerf-C)
MOE_DISPATCH: Optional[str] = None    # "gather" | "bcsr" | None (= cfg field)
MESH = None                           # concrete mesh for shard_map paths


@contextlib.contextmanager
def activation_specs(act: Optional[P] = None, moe: Optional[P] = None,
                     logit: Optional[P] = None, moe_groups: Optional[int] = None,
                     moe_combine: Optional[P] = None, moe_impl: str = "pjit",
                     moe_dispatch: Optional[str] = None, mesh=None):
    global ACT_SPEC, MOE_SPEC, LOGIT_SPEC, MOE_GROUPS, MOE_COMBINE_SPEC, \
        MOE_IMPL, MOE_DISPATCH, MESH
    prev = (ACT_SPEC, MOE_SPEC, LOGIT_SPEC, MOE_GROUPS, MOE_COMBINE_SPEC,
            MOE_IMPL, MOE_DISPATCH, MESH)
    ACT_SPEC, MOE_SPEC, LOGIT_SPEC, MOE_GROUPS, MOE_COMBINE_SPEC, \
        MOE_IMPL, MOE_DISPATCH, MESH = (act, moe, logit, moe_groups,
                                        moe_combine, moe_impl, moe_dispatch,
                                        mesh)
    try:
        yield
    finally:
        (ACT_SPEC, MOE_SPEC, LOGIT_SPEC, MOE_GROUPS, MOE_COMBINE_SPEC,
         MOE_IMPL, MOE_DISPATCH, MESH) = prev
