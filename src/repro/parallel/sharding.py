"""Sharding rules: FSDP x TP x EP x SP over the ("pod","data","model") mesh.

Discipline (the chiplet/D2D analogue from DESIGN.md S5):
* FSDP: every large parameter is sharded over the combined ("pod","data")
  axes *and* over "model" (2-D sharded matrices) -- ZeRO-3: optimizer states
  mirror the param specs.
* TP ("model"): head/ff/vocab/expert dims.
* EP: expert dim of MoE weights over "model"; token dispatch becomes an
  all-to-all under pjit.
* SP: when the batch is too small to fill the data axes (long-context
  decode), the sequence dim of activations/caches shards over "data".

Specs are derived from the *param tree paths*, so any pytree that mirrors the
params (grads, AdamW m/v) reuses the same rules verbatim.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

FSDP: Tuple[str, ...] = ("pod", "data")   # present axes are filtered per mesh
TP = "model"


def _filter(spec: P, mesh) -> P:
    """Drop mesh axes that don't exist (single-pod mesh has no 'pod')."""
    names = set(mesh.axis_names)

    def f(entry):
        if entry is None:
            return None
        if isinstance(entry, str):
            return entry if entry in names else None
        sub = tuple(a for a in entry if a in names)
        return sub if len(sub) > 1 else (sub[0] if sub else None)

    return P(*(f(e) for e in spec))


def _rule_for(path: Tuple[str, ...], shape: Tuple[int, ...]) -> P:
    """Map a param path (dict keys along the pytree) + shape to a spec.

    Scanned block params carry a leading repeat dim -> prepend None.
    """
    name = path[-1]
    parent = path[-2] if len(path) >= 2 else ""

    base = {
        # embeddings
        "embed": P(TP, FSDP),
        "unembed": P(FSDP, TP),
        # attention
        "wq": P(FSDP, TP), "wk": P(FSDP, TP), "wv": P(FSDP, TP),
        "wo": P(TP, FSDP),
        "bq": P(TP), "bk": P(TP), "bv": P(TP),
        # dense mlp
        "w_gate": P(FSDP, TP), "w_up": P(FSDP, TP), "w_down": P(TP, FSDP),
        # router
        "router": P(FSDP, None),
        # mamba
        "w_in": P(FSDP, TP), "w_out": P(TP, FSDP),
        "conv_w": P(None, TP), "conv_b": P(TP),
        "a_log": P(None), "d_skip": P(None), "dt_bias": P(None),
        # rwkv
        "w_r": P(FSDP, TP), "w_k": P(FSDP, TP), "w_v": P(FSDP, TP),
        "w_g": P(FSDP, TP), "w_o": P(TP, FSDP),
        "w_ck": P(FSDP, TP), "w_cv": P(TP, FSDP), "w_cr": P(FSDP, TP),
        "decay_lora_a": P(FSDP, None), "decay_lora_b": P(None, FSDP),
        "mu": P(None, FSDP), "mu_c": P(None, FSDP),
        "decay_base": P(FSDP), "bonus_u": P(None, None),
        # norms / scalars
        "scale": P(None),
    }
    spec = base.get(name)
    if spec is None:
        spec = P(*([None] * len(shape)))
    if parent == "experts":
        # MoE expert weights (E, d, ff): EP over model, FSDP over d/ff
        if name in ("w_gate", "w_up"):
            spec = P(TP, FSDP, None)
        elif name == "w_down":
            spec = P(TP, None, FSDP)
    # leading stacked-repeat dim?
    ndim_spec = len(spec)
    if len(shape) == ndim_spec + 1:
        spec = P(None, *spec)
    elif len(shape) != ndim_spec:
        spec = P(*([None] * len(shape)))
    return spec


def param_specs(params, mesh) -> Any:
    """PartitionSpec tree mirroring ``params``."""

    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(path + (k,), v) for k, v in node.items()}
        if isinstance(node, (tuple, list)):
            out = [walk(path + (str(i),), v) for i, v in enumerate(node)]
            return type(node)(out)
        return _filter(_rule_for(path, node.shape), mesh)

    return walk((), params)


def shardings(spec_tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(param_spec_tree, mesh):
    """AdamWState(m, v, count) mirrors the params; count replicated."""
    from repro.optim.adamw import AdamWState
    return AdamWState(m=param_spec_tree, v=param_spec_tree, count=P())


def batch_spec(batch: int, mesh, *, seq_shard: bool = False) -> P:
    """Tokens (B, S): batch over ("pod","data") when it divides; otherwise
    shard the sequence (SP) instead."""
    dp = _filter(P(FSDP), mesh)[0]
    if seq_shard:
        return P(None, "data" if "data" in mesh.axis_names else None)
    return P(dp, None)


def data_axis_size(mesh) -> int:
    n = 1
    for a in FSDP:
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def cache_specs(cache, cfg, mesh, *, batch: int) -> Any:
    """Decode-cache specs. Batch dim shards over ("pod","data") when
    possible; otherwise (long-context, B=1) the sequence/state dims shard:
    attention K/V over "data" (SP decode -- distributed online-softmax
    merge is inserted by SPMD), ssm/wkv head dims over "model"."""
    dp_size = data_axis_size(mesh)
    batch_ok = batch % dp_size == 0 and batch >= dp_size
    dp = _filter(P(FSDP), mesh)[0] if batch_ok else None

    def leaf_spec(path, x):
        name = path[-1]
        if name in ("k", "v"):
            # (L, B, Hkv, S, hd): batch over dp, sequence over "model" (the
            # online-softmax merge over seq shards is inserted by SPMD);
            # long-context (batch too small) shards seq over "data" instead.
            if batch_ok:
                return P(None, dp, None, TP, None)
            return P(None, None, None, "data", None)
        if name == "ssm":      # (L, B, nh, hd, ns)
            return P(None, dp, TP, None, None)
        if name == "wkv":      # (L, B, nh, hd, hd)
            return P(None, dp, TP, None, None)
        if name == "conv":     # (L, B, K-1, C)
            return P(None, dp, None, TP)
        if name in ("shift_t", "shift_c"):  # (L, B, 1, d)
            return P(None, dp, None, None)
        return P(*([None] * x.ndim))

    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(path + (k,), v) for k, v in node.items()}
        if isinstance(node, (tuple, list)):
            return type(node)(walk(path + (str(i),), v) for i, v in enumerate(node))
        return _filter(leaf_spec(path, node), mesh)

    return walk((), cache)


def constrain(x, spec: P):
    """with_sharding_constraint that is a no-op outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def compat_shard_map(f, *, mesh, in_specs, out_specs, check: bool = True):
    """shard_map across jax versions: ``jax.shard_map(check_vma=)`` on new
    jax, ``jax.experimental.shard_map.shard_map(check_rep=)`` on 0.4.x.
    ``check=False`` is required whenever the body contains a pallas_call
    (no replication/vma rule is registered for it)."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check)
        except TypeError:  # promotion window where the kwarg was check_rep
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)
