"""Zamba2-1.2B: Mamba2 backbone + one shared attention block applied
periodically. [arXiv:2411.15242; hf] 38L d_model=2048 32H (kv=32, MHA shared
block) d_ff=8192 vocab=32000, ssm_state=64.

38 mamba layers = 2 prologue + 6 repeats x 6; the shared block fires after
every repeat (6 sites), reusing ONE weight set (Zamba's design).
long_500k RUNS: SSM state is O(1); shared-attn caches are seq-sharded."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192, vocab_size=32000,
    block_unit=("mamba",) * 6, n_repeats=6, n_prologue=2,
    head_dim=64, shared_attn_every=1,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
    mlp_type="swiglu", rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="zamba2-1.2b-smoke", family="hybrid",
    d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
    block_unit=("mamba",) * 2, n_repeats=2, n_prologue=1,
    head_dim=16, shared_attn_every=1,
    ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_conv=4,
)
