"""Gemma3-12B: 5:1 local:global attention, 128k context.
[hf:google/gemma-3-* family; unverified] 48L d_model=3840 16H (GQA kv=8)
d_ff=15360 vocab=262144, sliding window 1024 on local layers.

long_500k RUNS for this arch: local KV caches are ring buffers bounded by
the window; global layers decode against the full (seq-sharded) cache."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b", family="dense",
    d_model=3840, n_heads=16, n_kv_heads=8, d_ff=15360, vocab_size=262144,
    block_unit=("attn_local",) * 5 + ("attn_global",), n_repeats=8,
    head_dim=256, qk_norm=True, local_window=1024,
    mlp_type="swiglu", rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="gemma3-12b-smoke", family="dense",
    d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
    block_unit=("attn_local",) * 2 + ("attn_global",), n_repeats=2,
    head_dim=16, qk_norm=True, local_window=16,
)
