"""MusicGen-large: decoder-only transformer over EnCodec tokens.
[arXiv:2306.05284; hf] 48L d_model=2048 32H (kv=32, MHA) d_ff=8192
vocab=2048. The EnCodec audio frontend is a STUB: input_specs() supplies
precomputed conditioning frame embeddings (B, 64, d) + code tokens."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio",
    d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192, vocab_size=2048,
    block_unit=("attn",), n_repeats=48, head_dim=64,
    mlp_type="swiglu", rope_theta=1e4,
    frontend="audio", frontend_tokens=64,
)

SMOKE = ArchConfig(
    name="musicgen-large-smoke", family="audio",
    d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=128,
    block_unit=("attn",), n_repeats=2, head_dim=16,
    frontend="audio", frontend_tokens=4,
)
