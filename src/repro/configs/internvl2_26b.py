"""InternVL2-26B: InternViT frontend (stub) + InternLM2 decoder backbone.

[arXiv:2404.16821; hf] 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553. The vision frontend is a STUB: input_specs() supplies
precomputed patch embeddings (B, 256, d)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm",
    d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384, vocab_size=92553,
    block_unit=("attn",), n_repeats=48, head_dim=128,
    mlp_type="swiglu", rope_theta=1e6,
    frontend="vision", frontend_tokens=256,
)

SMOKE = ArchConfig(
    name="internvl2-26b-smoke", family="vlm",
    d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=277,
    block_unit=("attn",), n_repeats=2, head_dim=16,
    mlp_type="swiglu", frontend="vision", frontend_tokens=8,
)
