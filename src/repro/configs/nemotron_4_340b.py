"""Nemotron-4-340B: dense, GQA, squared-ReLU MLP. [arXiv:2402.16819; unverified]
96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b", family="dense",
    d_model=18432, n_heads=96, n_kv_heads=8, d_ff=73728, vocab_size=256000,
    block_unit=("attn",), n_repeats=96, head_dim=192,
    mlp_type="squared_relu", rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="nemotron-4-340b-smoke", family="dense",
    d_model=96, n_heads=6, n_kv_heads=2, d_ff=384, vocab_size=256,
    block_unit=("attn",), n_repeats=2, head_dim=16, mlp_type="squared_relu",
)
