"""Qwen2-1.5B: dense, GQA kv=2, QKV bias. [arXiv:2407.10671; hf]
28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-1.5b", family="dense",
    d_model=1536, n_heads=12, n_kv_heads=2, d_ff=8960, vocab_size=151936,
    block_unit=("attn",), n_repeats=28, head_dim=128,
    qkv_bias=True, mlp_type="swiglu", rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="qwen2-1.5b-smoke", family="dense",
    d_model=48, n_heads=6, n_kv_heads=2, d_ff=96, vocab_size=256,
    block_unit=("attn",), n_repeats=2, head_dim=8, qkv_bias=True,
)
