"""Config registry: one module per assigned architecture (+ paper workloads).

Each module defines CONFIG (the exact assigned configuration) and SMOKE (a
reduced same-family config for CPU smoke tests). Use ``get_config(name)`` /
``get_smoke(name)`` / ``ARCH_NAMES``.
"""
from __future__ import annotations

import importlib

ARCH_NAMES = [
    "internvl2-26b",
    "qwen3-1.7b",
    "qwen2-1.5b",
    "gemma3-12b",
    "nemotron-4-340b",
    "llama4-maverick-400b-a17b",
    "llama4-scout-17b-a16e",
    "zamba2-1.2b",
    "musicgen-large",
    "rwkv6-7b",
]

_MODULES = {n: "repro.configs." + n.replace("-", "_").replace(".", "_")
            for n in ARCH_NAMES}


def _load(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    return importlib.import_module(_MODULES[name])


def get_config(name: str):
    return _load(name).CONFIG


def get_smoke(name: str):
    return _load(name).SMOKE
