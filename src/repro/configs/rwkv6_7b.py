"""RWKV-6 (Finch) 7B: attention-free, data-dependent per-channel decay.
[arXiv:2404.05892; hf] 32L d_model=4096 d_ff=14336 vocab=65536.

Attention-oriented streaming is inapplicable (no attention) -- see DESIGN.md
S4; the arch runs on affine-stream chunked WKV scans. long_500k RUNS:
state is O(1) in sequence length."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b", family="ssm",
    d_model=4096, n_heads=64, n_kv_heads=64, d_ff=14336, vocab_size=65536,
    block_unit=("rwkv",), n_repeats=32, head_dim=64,
    mlp_type="squared_relu",
)

SMOKE = ArchConfig(
    name="rwkv6-7b-smoke", family="ssm",
    d_model=128, n_heads=2, n_kv_heads=2, d_ff=448, vocab_size=256,
    block_unit=("rwkv",), n_repeats=2, head_dim=64,
    mlp_type="squared_relu",
)
