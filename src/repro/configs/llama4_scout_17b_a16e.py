"""Llama-4 Scout 17B-A16E: MoE every layer, 16 routed experts top-1 +
shared expert. [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192, vocab_size=202048,
    block_unit=("attn+moe",), n_repeats=48, head_dim=128,
    n_experts=16, top_k=1, moe_shared_expert=True,
    mlp_type="swiglu", rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="llama4-scout-smoke", family="moe",
    d_model=64, n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=256,
    block_unit=("attn+moe",), n_repeats=3, head_dim=16,
    n_experts=4, top_k=1, moe_shared_expert=True,
    capacity_factor=8.0,
)
