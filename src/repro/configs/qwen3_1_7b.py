"""Qwen3-1.7B: dense, qk_norm, GQA. [hf:Qwen/Qwen3-8B family; hf]
28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-1.7b", family="dense",
    d_model=2048, n_heads=16, n_kv_heads=8, d_ff=6144, vocab_size=151936,
    block_unit=("attn",), n_repeats=28, head_dim=128,
    qk_norm=True, mlp_type="swiglu", rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="qwen3-1.7b-smoke", family="dense",
    d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
    block_unit=("attn",), n_repeats=2, head_dim=16, qk_norm=True,
)
