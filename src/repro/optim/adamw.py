"""AdamW + schedules + clipping, as pure pytree transforms.

States are plain pytrees mirroring the params, so the FSDP sharding rules
apply verbatim (ZeRO-3 equivalence: m/v shards live with their param shard).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array
    master: Any = None   # f32 master copy when params live in bf16


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0
    # mixed-precision split (SPerf-A): model params stay bf16 (halves FSDP
    # all-gather bytes + param HBM); the f32 master lives here, sharded like
    # m/v (ZeRO-3).
    master_weights: bool = False

    def init(self, params) -> AdamWState:
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
                  if self.master_weights else None)
        return AdamWState(m=jax.tree.map(z, params),
                          v=jax.tree.map(z, params),
                          count=jnp.zeros((), jnp.int32),
                          master=master)

    def update(self, grads, state: AdamWState, params):
        count = state.count + 1
        if self.clip_norm is not None:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gn + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state.m, grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g, state.v, grads)
        c = count.astype(jnp.float32)
        bc1 = 1 - b1 ** c
        bc2 = 1 - b2 ** c
        lr = self.lr(count) if callable(self.lr) else self.lr

        def upd(p, mm, vv):
            step = (mm / bc1) / (jnp.sqrt(vv / bc2) + self.eps)
            wd = self.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
            return (p.astype(jnp.float32) - lr * (step + wd))

        base = state.master if state.master is not None else params
        new_master = jax.tree.map(upd, base, m, v)
        new_params = jax.tree.map(
            lambda nm, p: nm.astype(p.dtype), new_master, params)
        master_out = new_master if state.master is not None else None
        return new_params, AdamWState(m=m, v=v, count=count, master=master_out)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def cosine_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def lr(count):
        c = count.astype(jnp.float32)
        warm = peak_lr * c / max(warmup, 1)
        prog = jnp.clip((c - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(c < warmup, warm, cos)
    return lr
