"""Loop-aware HLO accounting for the roofline analysis.

XLA's ``compiled.cost_analysis()`` counts ``while`` bodies ONCE (verified
empirically), so a scanned N-layer model under-reports FLOPs/bytes by ~N x.
This module re-derives the three roofline terms from the compiled HLO text:

* computation graph + per-instruction shapes parsed from ``as_text()``;
* ``while`` trip counts recovered from the loop-condition constants (scans
  lower to ``i < N`` with a literal N);
* per-computation multipliers propagated through while/call/fusion edges;
* dot FLOPs computed exactly (output elements x contracted extent x 2);
* collective bytes = output bytes of all-gather / all-reduce / reduce-scatter
  / all-to-all / collective-permute (+ their -start variants);
* HBM-traffic proxy = output bytes + distinct operand bytes of top-level
  (post-fusion) instructions.

Shapes in the partitioned module are per-device, so all sums are per-device.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# type group is lazy: stops at the first ` opcode(` token, which skips over
# tuple types (incl. /*index=N*/ comments) that contain no `word(` pattern
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+"
    r"([a-zA-Z][\w\-]*)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")


def shape_bytes(type_str: str) -> int:
    """Total bytes of all array shapes appearing in an HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def shape_dims(type_str: str) -> Tuple[int, ...]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return ()
    dims = m.group(2)
    return tuple(int(d) for d in dims.split(",")) if dims else ()


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str  # operand list + attrs (raw)


def parse_computations(hlo: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if not line.startswith(" ") and ("->" in line) and line.rstrip().endswith("{"):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            comps[cur].append(Instr(m.group(1), m.group(2), m.group(3),
                                    m.group(4)))
    return comps


def _called_comps(instr: Instr) -> List[str]:
    out = []
    for key in ("condition=", "body=", "calls=", "to_apply=",
                "true_computation=", "false_computation=",
                "branch_computations="):
        i = instr.rest.find(key)
        if i < 0:
            continue
        seg = instr.rest[i + len(key):]
        if seg.startswith("{"):
            seg = seg[1 : seg.index("}")]
            out.extend(s.strip().lstrip("%") for s in seg.split(","))
        else:
            name = re.match(r"%?([\w.\-]+)", seg)
            if name:
                out.append(name.group(1))
    return out


def _while_trip(comps, cond_name: str) -> int:
    """Max integer constant in the loop-condition computation (scan lowers
    the bound as a literal); defaults to 1 when nothing is found."""
    best = 1
    for ins in comps.get(cond_name, []):
        if ins.op == "constant":
            m = re.search(r"constant\((-?\d+)\)", "constant(" + ins.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def computation_multipliers(comps: Dict[str, List[Instr]],
                            entry: str) -> Dict[str, int]:
    """Execution-count multiplier per computation (nested loops multiply)."""
    mult: Dict[str, int] = defaultdict(int)

    def visit(name: str, m: int):
        if m <= 0 or name not in comps:
            return
        mult[name] += m
        for ins in comps[name]:
            called = _called_comps(ins)
            if ins.op == "while":
                trip = 1
                body = cond = None
                for key, val in (("condition=", "cond"), ("body=", "body")):
                    i = ins.rest.find(key)
                    if i >= 0:
                        nm = re.match(r"%?([\w.\-]+)", ins.rest[i + len(key):])
                        if nm:
                            if val == "cond":
                                cond = nm.group(1)
                            else:
                                body = nm.group(1)
                if cond:
                    trip = _while_trip(comps, cond)
                if body:
                    visit(body, m * trip)
                if cond:
                    visit(cond, m * (trip + 1))
            else:
                for c in called:
                    visit(c, m)

    visit(entry, 1)
    return dict(mult)


def find_entry(hlo: str) -> str:
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
    return m.group(1) if m else "main"


COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops whose operands/outputs are real buffer traffic even on TPU (matmul-class
# reads/writes, data movement, collectives). Pure elementwise/broadcast/
# convert/compare/select/reduce chains fuse into their consumers on TPU and
# are excluded; `fusion` output+operands stand in for the whole fused group.
_RW_OPS = {"dot", "convolution", "custom-call", "fusion", "copy",
           "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
           "concatenate", "pad", "slice", "sort", "reduce-window",
           "transpose", "reduce"}


def fusion_body_comps(comps: Dict[str, List[Instr]]) -> set:
    """Computations reachable only as fusion bodies (register-level on TPU)."""
    bodies = set()
    for instrs in comps.values():
        for ins in instrs:
            if ins.op == "fusion":
                for c in _called_comps(ins):
                    bodies.add(c)
    # nested: computations called from fusion bodies are register-level too
    grew = True
    while grew:
        grew = False
        for b in list(bodies):
            for ins in comps.get(b, []):
                for c in _called_comps(ins):
                    if c not in bodies:
                        bodies.add(c)
                        grew = True
    return bodies


def analyze(hlo: str) -> Dict[str, float]:
    """Returns loop-aware totals: dot_flops, collective_bytes (by op),
    traffic_bytes (HBM proxy), plus instruction histograms."""
    comps = parse_computations(hlo)
    entry = find_entry(hlo)
    mult = computation_multipliers(comps, entry)
    fused = fusion_body_comps(comps)

    dot_flops = 0.0
    coll_bytes: Dict[str, float] = defaultdict(float)
    coll_bytes_corr: Dict[str, float] = {}
    traffic = 0.0
    op_hist: Dict[str, int] = defaultdict(int)

    for cname, instrs in comps.items():
        m = mult.get(cname, 0)
        if m == 0:
            continue
        shapes = {i.name: i.type_str for i in instrs}
        in_fusion = cname in fused
        # Pallas interpret-mode grid loops (multiplier far beyond any model
        # loop) carry the full operand arrays as loop state; their true HBM
        # traffic is exactly the block dynamic-slice/-update-slice transfers
        # (HBM<->VMEM), everything else being VMEM/register-level. Count only
        # those there; pass-through copies/fusions of the carried arrays are
        # not memory traffic.
        kernel_grid = m > 100_000
        for ins in instrs:
            op_hist[ins.op] += m
            out_b = shape_bytes(ins.type_str)
            if ins.op in ("dot", "convolution"):
                out_elems = 1
                for d in shape_dims(ins.type_str):
                    out_elems *= d
                # contracted extent from lhs shape + contracting dims
                ops = re.findall(r"%([\w.\-]+)", ins.rest)
                cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
                extent = 1
                if ops and cdims and ops[0] in shapes:
                    lhs_dims = shape_dims(shapes[ops[0]])
                    for ci in cdims.group(1).split(","):
                        if ci != "" and int(ci) < len(lhs_dims):
                            extent *= lhs_dims[int(ci)]
                dot_flops += m * 2.0 * out_elems * extent
            for cop in COLLECTIVES:
                if ins.op == cop or ins.op == cop + "-start":
                    coll_bytes[cop] += m * out_b
                    # TPU-lowering correction (EXPERIMENTS.md SMethod): the
                    # CPU pipeline (a) upcasts bf16 values to f32 before
                    # collectives (x2 bytes) and (b) lacks the all-reduce ->
                    # reduce-scatter reassociation pass (x2 bytes on grad
                    # reductions). Estimate the TPU bytes for the same
                    # program: halve f32 collective payloads, halve
                    # all-reduces.
                    corr = m * out_b
                    if "f32[" in ins.type_str:
                        corr *= 0.5
                    if cop == "all-reduce":
                        corr *= 0.5
                    coll_bytes_corr[cop] = coll_bytes_corr.get(cop, 0.0) + corr
            # HBM proxy: buffer-level ops outside fusion bodies only
            if not in_fusion and ins.op in _RW_OPS:
                if kernel_grid and ins.op not in (
                        "dynamic-slice", "dynamic-update-slice"):
                    continue
                if ins.op in ("dynamic-slice", "slice", "gather"):
                    # reads only the sliced region, not the whole operand
                    traffic += m * 2 * out_b
                    continue
                if ins.op == "dynamic-update-slice":
                    ops_names = re.findall(r"%([\w.\-]+)", ins.rest)
                    upd = (shape_bytes(shapes[ops_names[1]])
                           if len(ops_names) > 1 and ops_names[1] in shapes
                           else out_b)
                    traffic += m * 2 * upd  # read update + write region
                    continue
                opnd_b = 0
                seen = set()
                for on in re.findall(r"%([\w.\-]+)", ins.rest.split("),")[0]):
                    if on in shapes and on not in seen:
                        seen.add(on)
                        opnd_b += shape_bytes(shapes[on])
                traffic += m * (out_b + opnd_b)

    return {
        "dot_flops": dot_flops,
        "collective_bytes": dict(coll_bytes),
        "collective_bytes_total": float(sum(coll_bytes.values())),
        "collective_bytes_tpu_corrected": float(sum(coll_bytes_corr.values())),
        "traffic_bytes": traffic,
        "op_hist": {k: v for k, v in sorted(op_hist.items(),
                                            key=lambda kv: -kv[1])[:24]},
        "n_computations": len(comps),
    }
