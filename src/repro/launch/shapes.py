"""Assigned input-shape grid + abstract input specs for the dry-run.

Four shapes per architecture (the pool's definition):
  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> serve prefill
  decode_32k   seq 32,768  global_batch 128   -> serve_step (1 new token)
  long_500k    seq 524,288 global_batch 1     -> serve_step; SSM/hybrid/SWA only

``long_500k`` is skipped for pure full-attention archs (unbounded dense KV /
quadratic prefill) per DESIGN.md S4.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int
    microbatches: int = 1   # gradient-accumulation steps for train


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# archs whose attention is bounded (SWA ring buffers) or stateful (SSM/hybrid)
LONG_CONTEXT_OK = {"gemma3-12b", "zamba2-1.2b", "rwkv6-7b"}


def cell_is_runnable(cfg: ArchConfig, shape: ShapeSpec) -> bool:
    if shape.name == "long_500k":
        return cfg.name in LONG_CONTEXT_OK
    return True


def token_inputs(cfg: ArchConfig, shape: ShapeSpec):
    """Abstract (tokens, embeddings) for train/prefill kinds."""
    B = shape.global_batch
    s_front = cfg.frontend_tokens if cfg.frontend != "none" else 0
    s_text = shape.seq_len - s_front
    tokens = jax.ShapeDtypeStruct((B, s_text), jnp.int32)
    emb = None
    if s_front:
        emb = jax.ShapeDtypeStruct((B, s_front, cfg.d_model), jnp.bfloat16)
    return tokens, emb
