"""Production mesh construction (single-pod 16x16 and multi-pod 2x16x16).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count locks on first backend init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 ("data","model") per pod; 2x16x16 ("pod","data","model") for the
    dual-pod system (the dual-chiplet analogue -- DESIGN.md S5)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 1):
    """Small mesh over however many (possibly fake) local devices exist --
    used by tests and the smoke-scale distributed examples."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 3)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
