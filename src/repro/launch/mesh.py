"""Production mesh construction (single-pod 16x16 and multi-pod 2x16x16).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count locks on first backend init).

Version compat: newer jax exposes ``axis_types=`` on ``jax.make_mesh`` and a
``jax.set_mesh`` context; jax 0.4.x has neither.  ``compat_make_mesh`` /
``mesh_context`` paper over the difference so every mesh construction in the
repo goes through one door.
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """jax.make_mesh with Auto axis_types where supported (newer jax)."""
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def mesh_context(mesh):
    """``jax.set_mesh(mesh)`` on newer jax; the legacy ``Mesh`` context
    manager (which scopes pjit's implicit mesh) on 0.4.x."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 ("data","model") per pod; 2x16x16 ("pod","data","model") for the
    dual-pod system (the dual-chiplet analogue -- DESIGN.md S5)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 1):
    """Small mesh over however many (possibly fake) local devices exist --
    used by tests and the smoke-scale distributed examples."""
    if pod > 1:
        return compat_make_mesh((pod, data, model), ("pod", "data", "model"))
    return compat_make_mesh((data, model), ("data", "model"))
