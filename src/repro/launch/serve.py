"""Serving launcher: two-phase route-then-compile serving, single-run and
continuous-batching multi-tenant.

Two drivers share one phase machinery (:class:`_ServeBase`):

* :class:`ServeLoop` -- the static-batch driver: one prefill over a fixed
  (B, S) prompt batch, then lockstep decode.  Two modes:

  * **fused** (default for gather dispatch) -- the whole one-token decode
    step is one jit-compiled program (`model.decode_step`), the classic
    serving loop.  Greedy (temperature 0) decoding is token-for-token
    identical to the pre-ServeLoop smoke loop.
  * **two-phase** (default when the arch has MoE layers and the "bcsr"
    dispatch backend is selected) -- prefill AND each decode step run layer
    by layer (`model.prefill_layered` / `model.decode_step_layered`, every
    layer a cached jit-compiled step); at every attn+moe layer the loop
    *routes on host* (``moe.route_moe``: jitted router matmul, then
    compacts the dispatch matrix to its union nonzero-block stream, padded
    to a power-of-two nnzb bucket) and then calls the jit-compiled
    expert/combine phase (``moe.execute_moe_jit``) on that static-bucketed
    stream.  Recompiles stay bounded by the bucket count (see
    tests/README.md "two-phase serving contract").

* :class:`ServeScheduler` -- the continuous-batching frontend: a request
  queue with admission, join/evict *between decode steps* (finished or
  EOS'd sequences free their slot, queued prompts prefill into it), and
  per-request position / routing-occupancy / sampling state carried
  through the batch dim of the prefix-stable decode cache.  Decode steps
  run at a power-of-two *batch bucket* (``engine.batch_bucket`` -- the
  PR-3 nnzb bucket law extended to the batch dimension), so batch
  composition changes never retrace: compiled-step shapes are bounded by
  (batch buckets x nnzb buckets).  Per request the generated tokens are
  token-identical to running that request alone through a sequential
  :class:`ServeLoop` (enforced by tests/test_serve_scheduler.py) -- every
  per-row computation (attention at per-row positions, prefix-stable MoE
  occupancy, sampling keys) is independent of which neighbours share the
  batch.

Both drivers take a ``pipeline_depth`` knob (default 0):

* ``pipeline_depth=0`` -- fully serial, the pre-pipelining behavior
  bit-for-bit: every phase blocks on device results
  (``jax.block_until_ready``) before reading the clock and *drains* pending
  device work before starting a phase clock, so queued compute from the
  previous phase is never misattributed.
* ``pipeline_depth=1`` -- the pipelined hot path: each attn+moe layer's
  route phase 1 is fused into its jitted attention step (dispatched one
  program ahead; only the small slot stream is fetched to host, never the
  hidden state), the compiled execute phase stays *in flight* on the device
  behind the next layer's host route work (``engine.StreamPipeline``, the
  serving-loop analogue of the kernels' double-buffered K-tiles), and
  sampling runs on device so the only per-step host sync left is the token
  fetch (``ServeScheduler``) or nothing at all until the final drain
  (``ServeLoop``).  Generated tokens are bit-identical to depth 0
  (tests/test_serve_pipeline.py); ``summary()["timing"]`` reports how much
  route time the overlap actually hid (``route_hidden_frac``).

**Resilience** (``runtime.resilience``, tests/README.md "Resilience
contract"): both drivers take a deterministic ``fault_plan`` whose staged
hooks (prefill / route / execute / attention / sample / quantize) poison
rows, corrupt quant scales, raise, or straggle on demand.  The scheduler
isolates failures per request: cheap on-device ``isfinite`` health bits
piggyback on the existing per-step token fetch (zero NEW host syncs at
depth 1), a poisoned row is moved to a FAILED state, its cache row
scatter-blanked (``model.blank_cache_row``) and its slot refilled --
co-batched survivors' tokens stay bit-identical to a fault-free run
(per-row independence, the same law behind the batch-bucket contract).
Failed prefills and decode steps retry under a bounded exponential-backoff
``RetryPolicy`` (faults fire before any key split or cache write, so a
retry reproduces the fault-free step exactly); requests carry optional
TTFT/total deadlines and the admission queue is bounded with an explicit
shed policy.  Accumulated failures walk a ``DegradationLadder`` (quantized
KV -> wide, sparse mask -> ref, pipeline depth 1 -> 0); everything is
surfaced in ``summary()["health"]``.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --smoke \
      --batch 4 --prompt-len 32 --gen 32
  PYTHONPATH=src python -m repro.launch.serve --arch llama4-scout-17b-a16e \
      --smoke --dispatch bcsr --gen 16 --continuous --requests 6
"""
from __future__ import annotations

import argparse
import collections
import contextlib
import dataclasses
import functools
import time
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.core.masks import AttnMaskSpec
from repro.kernels import engine
from repro.kernels.flash_attention import ops as flash_ops
from repro.models import model as M
from repro.models import moe
from repro.parallel import context as pctx
from repro.runtime import resilience as R


@dataclasses.dataclass
class StepStat:
    """One timed phase of the loop; ``extra`` carries phase-specific detail
    (e.g. the route phase's nnzb stream accounting)."""
    phase: str          # prefill | route | execute | decode | sample
    step: int           # decode step index (-1 for prefill)
    seconds: float
    tokens: int = 0
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)


def _percentiles_ms(seconds: List[float]) -> Dict[str, float]:
    """p50/p99/mean of a latency sample, in milliseconds.

    Hardened for the failure paths: an empty sample (every request faulted
    or was shed before its first token) returns zeros, and None / non-finite
    entries (unset latency marks) are dropped rather than propagated into
    the percentiles."""
    seconds = [s for s in (seconds or [])
               if s is not None and np.isfinite(s)]
    if not seconds:
        return {"p50": 0.0, "p99": 0.0, "mean": 0.0, "n": 0}
    a = np.asarray(seconds, np.float64) * 1e3
    return {"p50": float(np.percentile(a, 50)),
            "p99": float(np.percentile(a, 99)),
            "mean": float(a.mean()), "n": int(a.size)}


def _sampler_body(vocab: int, temperature: float, per_row_keys: bool):
    """The sampling math shared by :func:`_sampler_jit` and
    :func:`_sampler_health_jit`: vocab slice, argmax or categorical --
    identical to the eager ``_sample``/``_sample_one``."""
    if temperature > 0:
        if per_row_keys:
            def fn(logits, keys):
                lg = logits[:, :vocab] / temperature
                return jax.vmap(jax.random.categorical)(
                    keys, lg).astype(jnp.int32)
        else:
            def fn(logits, key):
                lg = logits[:, :vocab] / temperature
                return jax.random.categorical(
                    key, lg)[:, None].astype(jnp.int32)
    else:
        if per_row_keys:
            def fn(logits, keys):
                return jnp.argmax(logits[:, :vocab],
                                  axis=-1).astype(jnp.int32)
        else:
            def fn(logits, key):
                return jnp.argmax(logits[:, :vocab],
                                  axis=-1)[:, None].astype(jnp.int32)
    return fn


@functools.lru_cache(maxsize=None)
def _sampler_jit(vocab: int, temperature: float, per_row_keys: bool):
    """On-device sampler for the pipelined hot path: the same math as the
    eager ``_sample``/``_sample_one`` (vocab slice, argmax or categorical),
    fused into one compiled program so the sampled token array can feed the
    next step without any host fetch of the logits.

    ``per_row_keys=False`` takes one key for the whole batch and returns
    ``(B, 1)`` int32 (the ``ServeLoop`` shape); ``per_row_keys=True`` takes
    a ``(B, 2)`` stack of per-request keys and vmaps the categorical over
    rows, returning ``(B,)`` int32 -- bit-identical per row to sampling
    that row alone with its own key (the scheduler's composition-
    independence law).  Greedy (temperature 0) ignores the key operand."""
    return jax.jit(_sampler_body(vocab, temperature, per_row_keys))


@functools.lru_cache(maxsize=None)
def _sampler_health_jit(vocab: int, temperature: float, per_row_keys: bool):
    """:func:`_sampler_jit` + per-row health bits, one compiled program.

    Returns ``(tokens, finite)`` where ``finite[b]`` is the
    ``all(isfinite)`` reduction of row ``b``'s vocab slice -- the poison
    detector.  The scheduler fetches both in the SAME ``jax.device_get``
    it already spends on the token ids, so per-request isolation costs
    zero additional host syncs at ``pipeline_depth=1``; token bits are
    untouched (the sampler body is shared verbatim)."""
    body = _sampler_body(vocab, temperature, per_row_keys)

    def fn(logits, key):
        fin = jnp.all(jnp.isfinite(logits[:, :vocab]), axis=-1)
        return body(logits, key), fin
    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _health_accum_jit(vocab: int):
    """Fold one decode step's last-position logits into a running per-row
    health mask, on device: ``acc & all(isfinite(row))``.  Dispatched (not
    fetched) per step, read back once at the end-of-run drain -- the
    ``ServeLoop`` health path stays sync-free."""
    return jax.jit(lambda lg, acc: acc & jnp.all(
        jnp.isfinite(lg[:, :vocab]), axis=-1))


class _ServeBase:
    """Phase machinery shared by the static-batch :class:`ServeLoop` and the
    continuous-batching :class:`ServeScheduler`: dispatch-backend selection,
    the two-phase route->execute MoE stage with honest per-phase timing, and
    the phase-2 compile-signature accounting."""

    def __init__(self, params, cfg, *, dispatch: Optional[str] = None,
                 two_phase: Optional[bool] = None, temperature: float = 0.0,
                 sample_seed: int = 3, pipeline_depth: int = 0,
                 quantize_experts: Optional[str] = None,
                 kv_quant: Optional[str] = None,
                 attn_mask: Optional[AttnMaskSpec] = None,
                 fault_plan: Optional[R.FaultPlan] = None,
                 retry: Optional[R.RetryPolicy] = None,
                 fail_threshold: int = 3):
        self.params, self.cfg = params, cfg
        self.quantize_experts = quantize_experts
        self.kv_quant = kv_quant
        self.attn_mask = attn_mask
        # baseline for the attention-fallback counter surfaced in
        # summary()["timing"]: only fallbacks observed by THIS driver count
        self._fallback_base = flash_ops.fallback_count()
        if quantize_experts:
            # opt-in narrow expert FFN weights: one-time host quantization,
            # QuantTensor leaves then flow through every execute path
            self.params = moe.quantize_model_experts(params, quantize_experts)
        self.backend = dispatch or cfg.moe_dispatch
        has_moe = any(k == "attn+moe" for k in cfg.block_unit)
        self.two_phase = ((self.backend == "bcsr" and has_moe)
                          if two_phase is None else two_phase)
        self.temperature = temperature
        self._sample_seed = sample_seed
        self._sample_key = jax.random.PRNGKey(sample_seed)
        self.stats: List[StepStat] = []
        self._exec_keys: set = set()   # distinct phase-2 compile signatures
        self.pipeline_depth = int(pipeline_depth)
        # validates the depth (0 = serial, 1 = double-buffered)
        self._pipe = engine.StreamPipeline(self.pipeline_depth)
        # -- resilience state (runtime.resilience) --------------------------
        self.fault_plan = fault_plan
        self.retry = retry if retry is not None else R.RetryPolicy()
        self.health = R.HealthTracker()
        self.ladder = R.DegradationLadder.for_serving(
            kv_quant=kv_quant, attn_mask=attn_mask,
            pipeline_depth=self.pipeline_depth,
            fail_threshold=fail_threshold)
        self._row_uids: Optional[List[Optional[int]]] = None

    # ---------------------------------------------------------- resilience --

    def _fault(self, stage: str, x, *, step: Optional[int] = None):
        """Fault-plan hook for a batched activation; identity w/o a plan."""
        if self.fault_plan is None:
            return x
        return self.fault_plan.apply(stage, x, step=step,
                                     uids=self._row_uids)

    def _fault_cache(self, cache, *, step: Optional[int] = None,
                     uids=None, nrows: int = 0):
        """Quantize-stage hook: corrupt cache scale rows per the plan."""
        if self.fault_plan is None:
            return cache
        return self.fault_plan.apply_cache(cache, step=step, uids=uids,
                                           nrows=nrows)

    def _note_failure(self):
        """Count one failure toward the degradation ladder; apply the rung
        it returns (if any) to this driver's live configuration."""
        rung = self.ladder.note_failure()
        if rung is not None:
            self._apply_rung(rung)

    def _apply_rung(self, rung: str):
        self.health.record("degrade", rung=rung)
        if rung == "kv_wide":
            # quantized KV -> wide f32 KV: rebuild the live cache without
            # scale leaves; subsequent prefills/steps see kv_quant=None
            self._pipe.abort()
            if getattr(self, "cache", None) is not None:
                self.cache = R.dequantize_cache(self.cache, jnp.float32)
            self.kv_quant = None
        elif rung == "mask_ref":
            # sparse stream-walk attention -> the jnp reference path
            self.attn_mask = dataclasses.replace(self.attn_mask, impl="ref")
        elif rung == "pipeline_serial":
            # depth 1 -> 0: drain what's in flight, go fully serial
            self._pipe.abort()
            self.pipeline_depth = 0
            self._pipe = engine.StreamPipeline(0)

    # ------------------------------------------------------------- phases --

    def _step_label(self) -> int:
        """Decode step index for phase stats (-1 = prefill)."""
        raise NotImplementedError

    @contextlib.contextmanager
    def _dispatch_ctx(self):
        """Trace-time backend override for the fused (in-jit) paths.

        Touches ONLY ``MOE_DISPATCH`` -- an ambient ``activation_specs``
        context (mesh, EP/combine layout constraints, dispatch groups) must
        survive into the trace, so this cannot re-enter that manager (which
        resets every global it does not receive)."""
        prev = pctx.MOE_DISPATCH
        pctx.MOE_DISPATCH = self.backend
        try:
            yield
        finally:
            pctx.MOE_DISPATCH = prev

    def _moe_two_phase(self, p_ffn, h, cfg, counts=None, pos=None,
                       phase1=None):
        """The route -> execute stage injected at every attn+moe layer.

        Serial mode (``pipeline_depth=0``): the drain on ``h`` happens
        BEFORE the route clock starts -- ``h`` is the async result of the
        attention half of the layer, and blocking on it inside the timer
        would charge that queued device compute to "route" (the pre-PR-6
        misattribution) -- and the execute result is blocked on, so every
        phase wall is honest device time.

        Pipelined mode (``pipeline_depth=1``): no drains anywhere.  The
        model's fused attention+route program already dispatched this
        layer's routing arrays (``phase1``), so the route stage is just the
        small slot-stream fetch + host compaction
        (``moe.plan_from_phase1``); the freshly dispatched execute is
        pushed into the stream pipeline instead of blocked on, riding in
        flight behind the *next* layer's host route work.  Route stats then
        carry ``hidden_s``: the fetch wait observed while an execute was
        genuinely still running on the device -- route time hidden behind
        device compute (0 by construction at depth 0)."""
        step = self._step_label()
        # fault hooks: "attention" poisons the attention half's output
        # feeding this layer, "route" fires before the host routing work
        # (exception kind = the host route failure mode).  Poisons are one
        # dispatched jnp.where each -- no sync, rows outside the spec's
        # selection are bit-identical untouched.
        h = self._fault("attention", h, step=step)
        h = self._fault("route", h, step=step)
        pipelined = self.pipeline_depth > 0
        drain_s = 0.0
        if not pipelined:
            t_d = time.monotonic()
            h = jax.block_until_ready(h)
            drain_s = time.monotonic() - t_d
        busy = pipelined and self._pipe.busy()
        t0 = time.monotonic()
        if phase1 is not None:
            plan, info = moe.plan_from_phase1(phase1, cfg,
                                              dispatch=self.backend,
                                              dtype=h.dtype)
        else:
            plan, info = moe.route_moe(p_ffn, h, cfg, counts=counts,
                                       pos=pos, dispatch=self.backend)
        self.stats.append(StepStat(
            "route", step, time.monotonic() - t0,
            tokens=h.shape[0] * h.shape[1],
            extra={**info, "drain_s": drain_s, "pipelined": pipelined,
                   "hidden_s": info.get("wait_s", 0.0) if busy else 0.0}))
        sig = (plan.capacity, plan.backend, tuple(h.shape),
               None if plan.stream is None
               else (plan.stream.nnzb,) + tuple(plan.stream.shape))
        self._exec_keys.add(sig)
        t0 = time.monotonic()
        out, new_counts = moe.execute_moe_jit(p_ffn, h, plan, cfg)
        out = self._fault("execute", out, step=step)
        # depth 0: push blocks immediately (the serial execute wall);
        # depth 1: the execute stays in flight behind the next host route
        self._pipe.push(plan, out)
        self.stats.append(StepStat(
            "execute", step, time.monotonic() - t0,
            tokens=h.shape[0] * h.shape[1],
            extra={"nnzb_stream": info.get("nnzb_stream"),
                   "compile_signatures": len(self._exec_keys),
                   "dispatch_only": pipelined}))
        return out, new_counts

    def _phase_summary(self) -> Dict[str, Any]:
        """Aggregate per-phase seconds / call counts.  The phases are NOT
        disjoint in two-phase mode: each "decode" step stat (and every
        "prefill" stat) times the whole layered pass, *inclusive* of the
        "route" / "execute" layer calls made inside it.

        ``timing`` is the attribution split: ``host_route_ms`` is the route
        phase minus its device fetch wait (pure host routing work),
        ``device_execute_ms`` / ``execute_dispatch_ms`` separate blocked
        execute walls (serial mode) from dispatch-only walls (pipelined
        mode) -- the pre-PR-7 summary folded the device-queue drain into
        whichever phase blocked first.  ``route_hidden_ms`` /
        ``route_hidden_frac`` report how much of the route phase ran while
        an execute was in flight on the device: the overlap efficiency of
        the pipelined mode, exactly 0 at depth 0."""
        out: Dict[str, Any] = {}
        for phase in ("prefill", "route", "execute", "decode", "drain"):
            ss = [s for s in self.stats if s.phase == phase]
            if ss:
                out[phase] = {"seconds": sum(s.seconds for s in ss),
                              "calls": len(ss)}
        fallbacks = flash_ops.fallback_count() - self._fallback_base
        routes = [s for s in self.stats if s.phase == "route"]
        execs = [s for s in self.stats if s.phase == "execute"]
        if routes or execs:
            route_s = sum(s.seconds for s in routes)
            wait_s = sum(s.extra.get("wait_s", 0.0) for s in routes)
            hidden_s = sum(s.extra.get("hidden_s", 0.0) for s in routes)
            out["timing"] = {
                "host_route_ms": (route_s - wait_s) * 1e3,
                "route_wait_ms": wait_s * 1e3,
                "attn_drain_ms": sum(s.extra.get("drain_s", 0.0)
                                     for s in routes) * 1e3,
                "device_execute_ms": sum(
                    s.seconds for s in execs
                    if not s.extra.get("dispatch_only")) * 1e3,
                "execute_dispatch_ms": sum(
                    s.seconds for s in execs
                    if s.extra.get("dispatch_only")) * 1e3,
                "route_hidden_ms": hidden_s * 1e3,
                "route_hidden_frac": (hidden_s / route_s
                                      if route_s > 0 else 0.0),
                "attention_ref_fallbacks": fallbacks,
            }
        elif fallbacks:
            # non-MoE (no route/execute stats) but the flash kernel silently
            # fell back to the jnp reference: still surface the count
            out["timing"] = {"attention_ref_fallbacks": fallbacks}
        if self.two_phase:
            streams = [s for s in routes if "nnzb_stream" in s.extra]
            if streams:
                out["stream"] = {
                    "nnzb_stream_mean": float(np.mean(
                        [s.extra["nnzb_stream"] for s in streams])),
                    "nnzb_routed_mean": float(np.mean(
                        [s.extra["nnzb_routed"] for s in streams])),
                    "grid_nnzb": streams[-1].extra["grid_nnzb"],
                }
            out["compile_signatures"] = len(self._exec_keys)
        out["pipeline"] = {"depth": self.pipeline_depth}
        # resilience surface: monotonic counters + bounded event log
        # (HealthTracker), the degradation-ladder position, and the exact
        # faults the plan fired (see tests/README.md "Resilience contract")
        out["health"] = {
            **self.health.snapshot(),
            "ladder": self.ladder.state(),
            "faults_triggered": (list(self.fault_plan.triggered)
                                 if self.fault_plan is not None else []),
        }
        return out


class ServeLoop(_ServeBase):
    """Batched greedy/temperature serving loop with KV caches.

    Parameters
    ----------
    params, cfg : the model.
    max_seq : static decode-cache capacity (prompt + generation).
    dispatch : MoE dispatch backend override ("gather" | "bcsr");
        default is the config's ``moe_dispatch`` field.
    two_phase : force the route-then-compile decode path on/off; default
        (None) enables it exactly when the arch has attn+moe layers and the
        backend is "bcsr" -- the combination where single-phase jit degrades
        to full-grid streams.
    temperature : 0 = greedy argmax, > 0 = categorical sampling.
    pipeline_depth : 0 = fully serial (every step blocks, the pre-PR-7
        behavior bit-for-bit); 1 = pipelined hot path (route-ahead fused
        programs, executes in flight behind host routing, on-device
        sampling -- token-identical to depth 0, see module docstring).
    quantize_experts : narrow dtype name ("fp8_e4m3" | "fp8_e5m2" | "int8")
        to BlockQuant the expert FFN weights at construction
        (``moe.quantize_model_experts``); None (default) leaves params
        untouched.
    kv_quant : narrow dtype name to store full-context KV caches as
        per-position narrow values + f32 scales (local ring buffers stay
        wide); None (default) keeps the wide cache bit-for-bit.
    fault_plan : optional ``resilience.FaultPlan`` whose staged hooks this
        loop calls at every prefill / route / execute / attention / sample /
        quantize boundary (identity when None).
    retry, fail_threshold : the resilience knobs shared with the scheduler
        (here the retry policy is only carried for ``summary()`` symmetry;
        the static-batch loop re-raises step failures after aborting the
        pipeline -- per-request retry lives in :class:`ServeScheduler`).
    """

    def __init__(self, params, cfg, *, max_seq: int,
                 dispatch: Optional[str] = None,
                 two_phase: Optional[bool] = None,
                 temperature: float = 0.0, sample_seed: int = 3,
                 pipeline_depth: int = 0,
                 quantize_experts: Optional[str] = None,
                 kv_quant: Optional[str] = None,
                 attn_mask: Optional[AttnMaskSpec] = None,
                 fault_plan: Optional[R.FaultPlan] = None,
                 retry: Optional[R.RetryPolicy] = None,
                 fail_threshold: int = 3):
        super().__init__(params, cfg, dispatch=dispatch, two_phase=two_phase,
                         temperature=temperature, sample_seed=sample_seed,
                         pipeline_depth=pipeline_depth,
                         quantize_experts=quantize_experts,
                         kv_quant=kv_quant, attn_mask=attn_mask,
                         fault_plan=fault_plan, retry=retry,
                         fail_threshold=fail_threshold)
        self.max_seq = max_seq
        self._decode_fused = jax.jit(
            lambda p, c, pos, tok: M.decode_step(p, cfg, c, pos, tok))
        self.cache = None
        self.pos: Optional[int] = None
        self.generated: List[jax.Array] = []
        # per-row health: a device-resident running isfinite mask,
        # accumulated per step (dispatch only) and fetched once per run
        self._health_dev: Optional[jax.Array] = None
        self.health_rows: Optional[np.ndarray] = None

    # ------------------------------------------------------------- phases --

    def _step_label(self) -> int:
        return len(self.generated) - 1

    def prefill(self, prompts: jax.Array,
                embeddings: Optional[jax.Array] = None) -> jax.Array:
        """Run the prompt through the model, fill the decode cache, and
        emit the first generated token (B, 1).

        Resets the generation state up front: the two-phase moe stage
        derives its step label from ``len(self.generated)``, which must
        read -1 (prefill) here even when a previous ``run`` left tokens
        behind.

        In two-phase mode the prompt runs through the *layered* prefill
        (``model.prefill_layered``) with the route->execute stage injected
        at every attn+moe layer, so prefill streams the bucketed routed
        dispatch stream too -- the fused ``model.prefill`` would trace the
        bcsr dispatch back to the full ``E*C x T`` grid (the single-phase
        fallback this loop exists to avoid)."""
        self.generated = []
        t0 = time.monotonic()
        if self.two_phase:
            logits, cache, pos = M.prefill_layered(
                self.params, prompts, self.cfg, max_seq=self.max_seq,
                embeddings=embeddings, moe_fn=self._moe_two_phase,
                route_ahead=self.pipeline_depth > 0,
                kv_quant=self.kv_quant, attn_mask=self.attn_mask)
        else:
            with self._dispatch_ctx():
                logits, cache, pos = M.prefill(self.params, prompts, self.cfg,
                                               max_seq=self.max_seq,
                                               embeddings=embeddings,
                                               kv_quant=self.kv_quant,
                                               attn_mask=self.attn_mask)
        logits, cache = jax.block_until_ready((logits, cache))
        self._pipe.drain()   # prefill executes all completed with logits
        logits = self._fault("prefill", logits, step=-1)
        cache = self._fault_cache(cache, step=-1,
                                  nrows=int(prompts.shape[0]))
        self.stats.append(StepStat(
            "prefill", -1, time.monotonic() - t0,
            tokens=int(np.prod(prompts.shape))))
        self.cache, self.pos = cache, int(pos)
        self._health_dev = jnp.all(
            jnp.isfinite(logits[:, -1, : self.cfg.vocab_size]), axis=-1)
        nxt = self._sample(logits[:, -1])
        self.generated = [nxt]
        return nxt

    def _sample(self, last_logits: jax.Array) -> jax.Array:
        lg = last_logits[:, : self.cfg.vocab_size]
        if self.temperature > 0:
            self._sample_key, k = jax.random.split(self._sample_key)
            nxt = jax.random.categorical(k, lg / self.temperature)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        return nxt[:, None].astype(jnp.int32)

    def _sample_device(self, last_logits: jax.Array) -> jax.Array:
        """Pipelined-mode sampling: same math as :meth:`_sample` (same key
        chain -- the split still happens eagerly on host), but the
        argmax/categorical runs as one jitted program whose (B, 1) token
        output feeds the next step's embedding lookup *on device* -- no
        host sync anywhere in the decode chain."""
        if self.temperature > 0:
            self._sample_key, k = jax.random.split(self._sample_key)
        else:
            k = self._sample_key   # unused by the greedy program
        return _sampler_jit(self.cfg.vocab_size, float(self.temperature),
                            False)(last_logits, k)

    def decode_step(self) -> jax.Array:
        """Generate one token for every sequence in the batch."""
        if self.cache is None:
            raise RuntimeError("decode_step before prefill")
        step = len(self.generated) - 1
        pos = self.pos + step
        if pos >= self.max_seq:
            # XLA clamps the out-of-bounds dynamic_update_slice instead of
            # failing, which would silently overwrite the LAST cache slot
            # every further step -- garbage tokens, no error.  Refuse.
            raise RuntimeError(
                f"ServeLoop.decode_step: KV-cache overflow -- decode write "
                f"position {pos} >= max_seq {self.max_seq} "
                f"(prefill filled {self.pos}, this is generated token "
                f"{step + 2}). Raise max_seq or generate fewer tokens.")
        tok = self.generated[-1]
        pipelined = self.pipeline_depth > 0
        self.cache = self._fault_cache(self.cache, step=step,
                                       nrows=int(tok.shape[0]))
        t0 = time.monotonic()
        if self.two_phase:
            logits, self.cache = M.decode_step_layered(
                self.params, self.cfg, self.cache, pos, tok,
                moe_fn=self._moe_two_phase, route_ahead=pipelined)
        else:
            with self._dispatch_ctx():
                logits, self.cache = self._decode_fused(
                    self.params, self.cache, jnp.asarray(pos, jnp.int32),
                    tok)
        logits = self._fault("sample", logits, step=step)
        if self._health_dev is not None:
            # dispatched, never fetched here: the run-end drain reads it
            self._health_dev = _health_accum_jit(self.cfg.vocab_size)(
                logits[:, -1], self._health_dev)
        if pipelined:
            # no host sync at all: the sampled token array feeds the next
            # step's embedding on device; the step wall is dispatch time
            # (the device drains at the end of decode() -- the drain stat)
            nxt = self._sample_device(logits[:, -1])
            self.stats.append(StepStat("decode", step,
                                       time.monotonic() - t0,
                                       tokens=tok.shape[0],
                                       extra={"dispatch_only": True}))
        else:
            t_b = time.monotonic()
            logits = jax.block_until_ready(logits)
            t_done = time.monotonic()
            self.stats.append(StepStat(
                "decode", step, t_done - t0, tokens=tok.shape[0],
                extra={"logits_wait_s": t_done - t_b}))
            nxt = self._sample(logits[:, -1])
        self.generated.append(nxt)
        return nxt

    def decode(self, n: int):
        for _ in range(n):
            self.decode_step()
        if self.pipeline_depth > 0 and self.generated:
            # the one host sync of the pipelined decode phase: drain the
            # whole dispatched chain (tokens + cache + in-flight executes)
            t0 = time.monotonic()
            jax.block_until_ready((self.generated[-1], self.cache))
            self._pipe.drain()
            self.stats.append(StepStat("drain", len(self.generated) - 2,
                                       time.monotonic() - t0))

    # -------------------------------------------------------------- drive --

    def run(self, prompts: jax.Array, gen: int,
            embeddings: Optional[jax.Array] = None,
            sample_key: Optional[jax.Array] = None) -> np.ndarray:
        """prefill + (gen - 1) decode steps; returns (B, gen) token ids.

        Every ``run`` starts from a *fresh* sampling key -- reseeded from
        the constructor's ``sample_seed`` (or ``sample_key`` when given) --
        so consecutive runs with ``temperature > 0`` are reproducible:
        before PR 6 the key advanced silently across runs, making every
        ``run()`` after the first irreproducible."""
        self.stats.clear()
        self._exec_keys.clear()
        self._fallback_base = flash_ops.fallback_count()
        self._pipe.drain()
        self._sample_key = (jax.random.PRNGKey(self._sample_seed)
                            if sample_key is None else sample_key)
        self._health_dev = None
        self.health_rows = None
        try:
            self.prefill(prompts, embeddings=embeddings)
            self.decode(gen - 1)
        except BaseException:
            # exception mid-run (host route failure, injected fault, ...):
            # release every in-flight execute so the loop object stays
            # usable -- a wedged StreamPipeline was the pre-resilience bug
            self._pipe.abort()
            raise
        if self._health_dev is not None:
            # the one health fetch of the run, at the existing drain point
            self.health_rows = np.asarray(self._health_dev)
            bad = int((~self.health_rows).sum())
            if bad:
                self.health.record("rows_poisoned", rows=int(bad))
        return np.asarray(jnp.concatenate(self.generated, axis=1))

    def summary(self) -> Dict[str, Any]:
        """Aggregate per-phase seconds / counts for the last ``run``.

        Note the phases are NOT disjoint in two-phase mode: each "decode"
        step stat (and the "prefill" stat) times the whole layered pass,
        *inclusive* of the "route" / "execute" layer calls made inside it
        (those entries break the pass down; do not sum them with "decode"
        or "prefill").

        In pipelined mode the decode-step stats are dispatch walls; the
        final "drain" stat is the real device wait, so tok/s is computed
        over decode + drain -- honest wall-clock either way."""
        out = self._phase_summary()
        dec = out.get("decode")
        if dec:
            wall = dec["seconds"] + out.get("drain", {}).get("seconds", 0.0)
            if wall > 0:
                batch = self.generated[0].shape[0] if self.generated else 0
                out["decode"]["tok_per_s"] = batch * dec["calls"] / wall
        if self.health_rows is not None:
            out["health"]["rows_finite"] = self.health_rows.tolist()
        return out


# ---------------------------------------------------- continuous batching --

@dataclasses.dataclass
class Request:
    """One user request in the continuous-batching scheduler.

    The scheduler fills in the lifecycle fields: ``tokens`` (generated ids),
    ``latencies_s`` (wall seconds of the step that emitted each token --
    the prefill pass for token 0, the shared decode step after), ``slot``
    (the cache batch row while resident), ``pos`` (next cache write
    position), and the timing marks used for first-token latency.

    ``state`` walks ``queued -> active -> finished`` on the happy path;
    the resilience layer adds ``failed`` (poisoned row or exhausted prefill
    retries -- ``fail_reason`` says why) and ``shed`` (bounded-queue
    admission rejection or an expired deadline before residency).
    ``ttft_deadline_s`` / ``deadline_s`` are optional wall-clock budgets
    from submit time to first token / final token."""
    prompt: np.ndarray
    max_new_tokens: int
    eos_id: Optional[int] = None
    uid: int = -1
    tokens: List[int] = dataclasses.field(default_factory=list)
    latencies_s: List[float] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    pos: int = 0
    done: bool = False
    submit_time: float = 0.0
    first_token_s: Optional[float] = None
    key: Optional[jax.Array] = None    # per-request sampling key chain
    state: str = "queued"              # queued|active|finished|failed|shed
    fail_reason: Optional[str] = None
    retries: int = 0
    ttft_deadline_s: Optional[float] = None
    deadline_s: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.prompt).size)


class ServeScheduler(_ServeBase):
    """Continuous-batching multi-tenant serving frontend.

    A queue of :class:`Request`\\ s is served by a fixed pool of cache
    *slots* (batch rows of one shared decode cache).  Between decode steps
    the scheduler **evicts** finished sequences (token budget reached or
    EOS) and **admits** queued prompts into the freed rows: each admission
    runs a single-request prefill (fused or layered two-phase, same as
    :class:`ServeLoop`) and scatters the resulting cache into the slot row
    -- attention KV, MoE routing occupancy, and recurrent state are all
    batch-row-indexed (see ``model.init_cache``), so neighbours are
    untouched.  Decode then advances *every* resident sequence one token in
    a single batched step at per-row positions.

    **Batch-bucket law.**  The decode step runs on cache rows
    ``[0, batch_bucket(highest occupied slot + 1))`` --
    ``engine.batch_bucket`` is the PR-3 power-of-two stream-bucket law
    applied to the batch dim -- so the compiled decode-step shapes (and the
    phase-2 execute signatures in two-phase mode) are bounded by
    (batch buckets x nnzb buckets), never one per occupancy pattern.
    Vacant rows inside the bucket still compute (their results are masked
    at sampling and their cache rows are fully overwritten at the next
    admission); per-row independence keeps them from perturbing residents.

    **Per-request determinism.**  Sampling state is per request (a key
    chain folded from ``sample_seed`` and the request uid), so a request's
    tokens do not depend on batch composition; at temperature 0 the
    generated tokens are token-identical to a sequential single-request
    :class:`ServeLoop` with the same ``max_seq``.
    """

    def __init__(self, params, cfg, *, max_seq: int, max_slots: int = 8,
                 dispatch: Optional[str] = None,
                 two_phase: Optional[bool] = None,
                 temperature: float = 0.0, sample_seed: int = 3,
                 batch_min_bucket: int = 1, cache_dtype=jnp.bfloat16,
                 pipeline_depth: int = 0,
                 quantize_experts: Optional[str] = None,
                 kv_quant: Optional[str] = None,
                 attn_mask: Optional[AttnMaskSpec] = None,
                 fault_plan: Optional[R.FaultPlan] = None,
                 retry: Optional[R.RetryPolicy] = None,
                 fail_threshold: int = 3,
                 max_queue: Optional[int] = None,
                 shed_policy: str = "reject",
                 clock=None):
        super().__init__(params, cfg, dispatch=dispatch, two_phase=two_phase,
                         temperature=temperature, sample_seed=sample_seed,
                         pipeline_depth=pipeline_depth,
                         quantize_experts=quantize_experts,
                         kv_quant=kv_quant, attn_mask=attn_mask,
                         fault_plan=fault_plan, retry=retry,
                         fail_threshold=fail_threshold)
        self.max_seq = max_seq
        self.batch_min_bucket = batch_min_bucket
        # allocate the slot pool at its own bucket so every step bucket,
        # clamped by the pool, is still a power of two
        self.n_slots = engine.batch_bucket(max_slots,
                                           minimum=batch_min_bucket)
        self.cache_dtype = cache_dtype
        self.cache = M.init_cache(cfg, self.n_slots, max_seq,
                                  dtype=cache_dtype, kv_quant=kv_quant)
        self.slots: List[Optional[Request]] = [None] * self.n_slots
        self.queue: Deque[Request] = collections.deque()
        self.finished: List[Request] = []
        self.failed: List[Request] = []
        self.shed: List[Request] = []
        if shed_policy not in ("reject", "drop_oldest"):
            raise ValueError("shed_policy must be 'reject' or 'drop_oldest'")
        self.max_queue = max_queue
        self.shed_policy = shed_policy
        # injectable time/sleep so deadline & backoff tests run on a fake
        # clock instead of wall time
        self._clock = clock if clock is not None else time.monotonic
        self._sleep = time.sleep
        self.step_idx = 0
        self._stat_step = -1
        self._next_uid = 0
        self.batch_buckets: set = set()
        self._decode_fused = jax.jit(
            lambda p, c, pos, tok: M.decode_step(p, cfg, c, pos, tok))

    # -------------------------------------------------------------- admit --

    def _step_label(self) -> int:
        return self._stat_step

    def submit(self, prompt, max_new_tokens: int,
               eos_id: Optional[int] = None,
               ttft_deadline_s: Optional[float] = None,
               deadline_s: Optional[float] = None) -> Request:
        """Queue a request.  Admission control happens here: a request whose
        prompt + generation budget cannot fit the cache is refused up front
        (its final token is sampled but never written, hence the ``- 1``),
        and a full bounded queue (``max_queue``) sheds per ``shed_policy``
        -- ``"reject"`` raises :class:`resilience.ShedError` at the caller,
        ``"drop_oldest"`` sheds the oldest queued request to make room.
        ``ttft_deadline_s`` / ``deadline_s`` bound submit->first-token /
        submit->completion wall time; expired requests are shed (queued) or
        failed (resident) at the next scheduler tick."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if max_new_tokens < 1:
            raise ValueError("submit: max_new_tokens must be >= 1")
        need = prompt.size + max_new_tokens - 1
        if need > self.max_seq:
            raise ValueError(
                f"submit: request needs {need} cache positions "
                f"({prompt.size} prompt + {max_new_tokens} generated - 1) "
                f"but max_seq is {self.max_seq}; it could never be served "
                "without a KV-cache overflow.")
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            if self.shed_policy == "reject":
                self.health.record("shed", reason="queue_full",
                                   uid=self._next_uid)
                raise R.ShedError(
                    f"submit: admission queue full ({len(self.queue)} >= "
                    f"max_queue {self.max_queue}); request rejected "
                    f"(shed_policy='reject')")
            # drop_oldest: the oldest *queued* (never-resident) request
            # yields its place to the newcomer
            self._shed(self.queue.popleft(), "queue_full_drop_oldest")
        req = Request(prompt=prompt, max_new_tokens=max_new_tokens,
                      eos_id=eos_id, uid=self._next_uid,
                      submit_time=self._clock(),
                      ttft_deadline_s=ttft_deadline_s,
                      deadline_s=deadline_s,
                      key=jax.random.fold_in(
                          jax.random.PRNGKey(self._sample_seed),
                          self._next_uid))
        self._next_uid += 1
        self.queue.append(req)
        return req

    def _sample_one(self, logits_row: jax.Array, req: Request) -> int:
        lg = logits_row[: self.cfg.vocab_size]
        if self.temperature > 0:
            req.key, k = jax.random.split(req.key)
            return int(jax.random.categorical(k, lg / self.temperature))
        return int(jnp.argmax(lg))

    def _finish_or_keep(self, req: Request, tok: int):
        if len(req.tokens) >= req.max_new_tokens or (
                req.eos_id is not None and tok == req.eos_id):
            self._evict(req)

    def _evict(self, req: Request):
        self.slots[req.slot] = None
        req.slot = None
        req.done = True
        req.state = "finished"
        self.finished.append(req)

    # -------------------------------------------------- failure lifecycle --

    def _fail(self, req: Request, reason: str, *, poisoned: bool = False):
        """Move a request to the FAILED terminal state.  A poisoned
        resident additionally gets its cache row scatter-blanked
        (``model.blank_cache_row``) so stale NaN/Inf state cannot leak into
        the admission that refills the slot; neighbouring rows -- and
        therefore every surviving request's tokens -- are untouched."""
        if req.slot is not None:
            slot = req.slot
            self.slots[slot] = None
            req.slot = None
            if poisoned:
                self.cache = M.blank_cache_row(self.cache, slot)
        req.done = True
        req.state = "failed"
        req.fail_reason = reason
        self.failed.append(req)
        self.health.record("request_failed", uid=req.uid, reason=reason)
        self._note_failure()

    def _shed(self, req: Request, reason: str):
        """Shed a queued (never-resident) request: terminal, no cache work."""
        req.done = True
        req.state = "shed"
        req.fail_reason = reason
        self.shed.append(req)
        self.health.record("shed", reason=reason, uid=req.uid)

    def _shed_expired(self, now: float):
        """Enforce deadlines at tick boundaries: queued requests past their
        TTFT or total deadline are shed; residents past their total
        deadline are failed (their row is clean -- no blanking needed)."""
        if self.queue:
            keep: Deque[Request] = collections.deque()
            while self.queue:
                r = self.queue.popleft()
                waited = now - r.submit_time
                if r.deadline_s is not None and waited > r.deadline_s:
                    self._shed(r, "deadline")
                elif (r.ttft_deadline_s is not None
                        and waited > r.ttft_deadline_s):
                    self._shed(r, "ttft_deadline")
                else:
                    keep.append(r)
            self.queue = keep
        for r in list(self.active):
            if (r.deadline_s is not None
                    and now - r.submit_time > r.deadline_s):
                self._fail(r, "deadline")

    def _prefill_into(self, req: Request, slot: int) -> bool:
        """Single-request prefill into cache row ``slot``, with bounded
        exponential-backoff retry (``RetryPolicy``).  Failed attempts --
        a host-side exception anywhere in the layered pass, or non-finite
        first-token logits -- leave the shared cache and the request's key
        chain untouched (the health check runs BEFORE the scatter and
        before any key split), so a retry reproduces the fault-free
        prefill bit-for-bit.  Returns False once retries are exhausted
        (the request is moved to FAILED and the slot stays free)."""
        last_reason = "prefill_failed"
        for attempt in range(self.retry.max_retries + 1):
            if attempt:
                req.retries += 1
                self.health.record("retry", stage="prefill", uid=req.uid,
                                   attempt=attempt)
                delay = self.retry.delay(attempt - 1)
                if delay:
                    self._sleep(delay)
            try:
                ok = self._prefill_attempt(req, slot)
            except Exception as e:
                self._pipe.abort()
                last_reason = f"prefill_error:{type(e).__name__}"
                self.health.record("prefill_error", uid=req.uid,
                                   error=type(e).__name__)
                self._note_failure()
                continue
            if ok:
                return True
            last_reason = "prefill_poisoned"
            self.health.record("prefill_poisoned", uid=req.uid)
            self._note_failure()
        self._fail(req, last_reason)
        return False

    def _prefill_attempt(self, req: Request, slot: int) -> bool:
        """One prefill try; False = non-finite logits (poisoned)."""
        self._stat_step = -1
        self._row_uids = [req.uid]
        prompts = jnp.asarray(req.prompt[None, :])
        t0 = time.monotonic()
        try:
            if self.two_phase:
                logits, cache1, pos = M.prefill_layered(
                    self.params, prompts, self.cfg, max_seq=self.max_seq,
                    cache_dtype=self.cache_dtype, moe_fn=self._moe_two_phase,
                    route_ahead=self.pipeline_depth > 0,
                    kv_quant=self.kv_quant, attn_mask=self.attn_mask)
            else:
                with self._dispatch_ctx():
                    logits, cache1, pos = M.prefill(
                        self.params, prompts, self.cfg, max_seq=self.max_seq,
                        cache_dtype=self.cache_dtype, kv_quant=self.kv_quant,
                        attn_mask=self.attn_mask)
            logits, cache1 = jax.block_until_ready((logits, cache1))
            self._pipe.drain()  # prefill executes all completed with logits
            logits = self._fault("prefill", logits)
        finally:
            self._row_uids = None
        dt = time.monotonic() - t0
        self.stats.append(StepStat("prefill", self.step_idx, dt,
                                   tokens=req.prompt_len,
                                   extra={"uid": req.uid, "slot": slot}))
        # the poison gate, BEFORE the scatter and before any key split:
        # a failed attempt leaves shared + per-request state untouched.
        # prefill already syncs, so this (vocab,) fetch adds no sync point.
        last_row = np.asarray(logits[0, -1, : self.cfg.vocab_size])
        if not np.isfinite(last_row).all():
            return False
        # one scatter per cache leaf: row `slot` becomes this request, every
        # other row's state is untouched
        self.cache = jax.tree.map(
            lambda big, small: big.at[:, slot].set(
                small[:, 0].astype(big.dtype)),
            self.cache, cache1)
        req.slot, req.pos = slot, int(pos)
        req.state = "active"
        self.slots[slot] = req
        # quantize-stage faults corrupt the freshly scattered row's scale
        # leaves (detected as poison at this request's next sampled logits)
        self.cache = self._fault_cache(
            self.cache, uids=[r.uid if r is not None else None
                              for r in self.slots], nrows=self.n_slots)
        tok = self._sample_one(logits[0, -1], req)
        req.tokens.append(tok)
        req.latencies_s.append(dt)
        req.first_token_s = self._clock() - req.submit_time
        self._finish_or_keep(req, tok)
        return True

    def admit(self) -> List[Request]:
        """Prefill queued requests into free slots (lowest index first --
        keeps the occupied prefix, and so the step's batch bucket, small).
        A request whose prefill exhausts its retries is FAILED and the
        slot offered to the next queued request."""
        joined = []
        while self.queue and None in self.slots:
            req = self.queue.popleft()
            if self._prefill_into(req, self.slots.index(None)):
                joined.append(req)
        return joined

    # ------------------------------------------------------------- decode --

    @property
    def active(self) -> List[Request]:
        return [r for r in self.slots if r is not None]

    def decode_step(self) -> List[Tuple[Request, int]]:
        """One batched decode step over the occupied slot prefix; returns
        the (request, token) pairs emitted.

        Failure handling (the per-request isolation contract,
        tests/test_resilience.py): a host-side exception anywhere in the
        step aborts the stream pipeline and retries the whole step under
        the ``RetryPolicy`` -- nothing was committed (no cache write, no
        key split, no token append happens before the failure can
        surface), so the retry reproduces the fault-free step exactly.  A
        *poisoned* row (non-finite sampled logits, detected by health bits
        piggybacked on the token fetch) fails only ITS request: the row is
        evicted and scatter-blanked, the token discarded, and every
        co-batched survivor keeps bit-identical tokens (per-row
        independence of attention / prefix-stable MoE / sampling)."""
        active = self.active
        if not active:
            return []
        for r in active:
            if r.pos >= self.max_seq:
                # admission control makes this unreachable for well-formed
                # requests; keep the guard -- the fused jit path cannot
                # host-check and would silently clamp the cache write
                raise RuntimeError(
                    f"ServeScheduler.decode_step: KV-cache overflow -- "
                    f"request {r.uid} at write position {r.pos} >= max_seq "
                    f"{self.max_seq}.")
        err: Optional[Exception] = None
        for attempt in range(self.retry.max_retries + 1):
            if attempt:
                self.health.record("retry", stage="decode",
                                   step=self.step_idx, attempt=attempt)
                delay = self.retry.delay(attempt - 1)
                if delay:
                    self._sleep(delay)
            try:
                return self._decode_attempt(active)
            except Exception as e:
                self._pipe.abort()
                err = e
                self.health.record("decode_error", step=self.step_idx,
                                   error=type(e).__name__)
                self._note_failure()
        raise RuntimeError(
            f"ServeScheduler.decode_step: step {self.step_idx} failed "
            f"after {self.retry.max_retries} retries") from err

    def _decode_attempt(self, active: List[Request]) -> List[Tuple[Request, int]]:
        """One decode-step try over the occupied slot prefix."""
        hi = max(i for i, r in enumerate(self.slots) if r is not None) + 1
        bucket = engine.batch_bucket(hi, minimum=self.batch_min_bucket,
                                     cap=self.n_slots)
        self.batch_buckets.add(bucket)
        pos_vec = np.zeros(bucket, np.int32)
        tok_vec = np.zeros((bucket, 1), np.int32)
        for i, r in enumerate(self.slots[:bucket]):
            if r is not None:
                pos_vec[i] = r.pos
                tok_vec[i, 0] = r.tokens[-1]
        # quantize-stage faults corrupt live scale rows mid-stream
        self.cache = self._fault_cache(
            self.cache, step=self.step_idx,
            uids=[r.uid if r is not None else None for r in self.slots],
            nrows=self.n_slots)
        step_cache = jax.tree.map(lambda a: a[:, :bucket], self.cache)
        self._stat_step = self.step_idx
        self._row_uids = [r.uid if r is not None else None
                          for r in self.slots[:bucket]]
        pipelined = self.pipeline_depth > 0
        t0 = time.monotonic()
        try:
            if self.two_phase:
                logits, new_cache = M.decode_step_layered(
                    self.params, self.cfg, step_cache, pos_vec,
                    jnp.asarray(tok_vec), moe_fn=self._moe_two_phase,
                    route_ahead=pipelined)
            else:
                with self._dispatch_ctx():
                    logits, new_cache = self._decode_fused(
                        self.params, step_cache, jnp.asarray(pos_vec),
                        jnp.asarray(tok_vec))
            # the sample hook fires BEFORE any per-request key split below,
            # so a sample-stage exception retries with key chains intact
            logits = self._fault("sample", logits, step=self.step_idx)
        finally:
            self._row_uids = None
        toks = None
        if pipelined:
            # sample on device (per-request key chains advance on host,
            # exactly as _sample_one's) and fetch the (bucket,) token ids
            # PLUS the per-row isfinite health bits in the single
            # device_get the scheduler already cannot shed: EOS / eviction
            # decisions need the values.  Zero additional host syncs.
            if self.temperature > 0:
                keys, dummy = [], None
                for r in self.slots[:bucket]:
                    if r is not None:
                        r.key, k = jax.random.split(r.key)
                        keys.append(k)
                    else:   # vacant row: sampled then masked; any key works
                        if dummy is None:
                            dummy = jnp.zeros((2,), jnp.uint32)
                        keys.append(dummy)
                key_arr = jnp.stack(keys)
            else:
                key_arr = jnp.zeros((bucket, 2), jnp.uint32)
            toks_dev, fin_dev = _sampler_health_jit(
                self.cfg.vocab_size, float(self.temperature), True)(
                    logits[:, -1], key_arr)
            toks, fin = jax.device_get((toks_dev, fin_dev))
            toks, fin = np.asarray(toks), np.asarray(fin)
        else:
            logits = jax.block_until_ready(logits)
            fin = np.asarray(jnp.all(jnp.isfinite(
                logits[:, -1, : self.cfg.vocab_size]), axis=-1))
        dt = time.monotonic() - t0
        self.stats.append(StepStat(
            "decode", self.step_idx, dt, tokens=len(active),
            extra={"batch_bucket": bucket, "active": len(active),
                   "pipelined": pipelined}))
        self.cache = jax.tree.map(
            lambda big, small: big.at[:, :bucket].set(
                small.astype(big.dtype)),
            self.cache, new_cache)
        emitted = []
        for i, r in enumerate(self.slots[:bucket]):
            if r is None:
                continue   # vacant bucket row: computed, masked out here
            if not fin[i]:
                # poisoned row: fail + evict + blank THIS request only;
                # survivors' rows were computed row-independently and are
                # committed above bit-identically to a fault-free step
                self._fail(r, f"poisoned:step{self.step_idx}",
                           poisoned=True)
                continue
            tok = (int(toks[i]) if toks is not None
                   else self._sample_one(logits[i, -1], r))
            r.tokens.append(tok)
            r.latencies_s.append(dt)
            r.pos += 1
            emitted.append((r, tok))
            self._finish_or_keep(r, tok)
        return emitted

    # -------------------------------------------------------------- drive --

    def step(self) -> List[Tuple[Request, int]]:
        """One scheduler tick: enforce deadlines, admit into freed slots,
        then decode one token for every resident sequence."""
        self._shed_expired(self._clock())
        self.admit()
        out = self.decode_step()
        self.step_idx += 1
        return out

    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)

    def run(self, max_steps: int = 1_000_000) -> Dict[int, np.ndarray]:
        """Drive until queue and slots drain (or ``max_steps`` ticks);
        returns {uid: generated token ids} over all finished requests."""
        steps = 0
        while self.has_work() and steps < max_steps:
            self.step()
            steps += 1
        return {r.uid: np.asarray(r.tokens, np.int32)
                for r in self.finished}

    def summary(self) -> Dict[str, Any]:
        """Aggregate serving stats: per-phase seconds (decode inclusive of
        route/execute in two-phase mode, as in :class:`ServeLoop`), decode
        tok/s over *emitted* tokens, per-token and first-token latency
        percentiles, and the bucket accounting that bounds recompiles."""
        out = self._phase_summary()
        dec = out.get("decode")
        if dec and dec["seconds"] > 0:
            emitted = sum(s.tokens for s in self.stats if s.phase == "decode")
            out["decode"]["tokens"] = emitted
            out["decode"]["tok_per_s"] = emitted / dec["seconds"]
        reqs = self.finished + self.active
        lat = [s for r in reqs for s in r.latencies_s]
        out["token_latency_ms"] = _percentiles_ms(lat)
        out["first_token_ms"] = _percentiles_ms(
            [r.first_token_s for r in reqs if r.first_token_s is not None])
        out["requests"] = {"finished": len(self.finished),
                           "queued": len(self.queue),
                           "active": len(self.active),
                           "failed": len(self.failed),
                           "shed": len(self.shed),
                           "retries": sum(r.retries for r in
                                          self.finished + self.failed
                                          + self.active)}
        out["health"]["failed"] = [
            {"uid": r.uid, "reason": r.fail_reason} for r in self.failed]
        out["health"]["shed"] = [
            {"uid": r.uid, "reason": r.fail_reason} for r in self.shed]
        out["batch_buckets"] = sorted(self.batch_buckets)
        if self.two_phase:
            out["nnzb_buckets"] = sorted(
                {sig[3][0] for sig in self._exec_keys
                 if sig[3] is not None})
        return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--dispatch", choices=["config", "gather", "bcsr"],
                    default="config",
                    help="MoE dispatch backend (config = the arch's field)")
    ap.add_argument("--two-phase", choices=["auto", "on", "off"],
                    default="auto",
                    help="route-then-compile decode (auto = when moe+bcsr)")
    ap.add_argument("--pipeline-depth", type=int, choices=[0, 1], default=0,
                    help="0 = serial (block every phase), 1 = pipelined "
                         "(route-ahead + in-flight executes + on-device "
                         "sampling; token-identical to 0)")
    ap.add_argument("--continuous", action="store_true",
                    help="drive the continuous-batching scheduler on a "
                         "synthetic multi-user trace instead of one static "
                         "batch")
    ap.add_argument("--requests", type=int, default=8,
                    help="--continuous: number of synthetic requests")
    ap.add_argument("--slots", type=int, default=4,
                    help="--continuous: resident slot pool size")
    ap.add_argument("--quantize-experts", default=None,
                    choices=["fp8_e4m3", "fp8_e5m2", "int8"],
                    help="BlockQuant the expert FFN weights to this narrow "
                         "dtype (per-output-channel f32 scales)")
    ap.add_argument("--kv-quant", default=None,
                    choices=["fp8_e4m3", "fp8_e5m2", "int8"],
                    help="store full-context KV caches as narrow values + "
                         "per-position f32 scales")
    ap.add_argument("--attn-mask", default="none",
                    choices=["none", "sliding", "local_global", "strided"],
                    help="route prefill attention through the block-sparse "
                         "stream walk: 'sliding' = local layers only (each "
                         "layer's own window), others additionally impose "
                         "the named long-context pattern on full-attention "
                         "layers")
    ap.add_argument("--attn-mask-impl", default="sparse",
                    choices=["sparse", "dense", "ref"],
                    help="masked-attention implementation (dense/ref are "
                         "the parity baselines)")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    max_seq = args.prompt_len + args.gen + (
        cfg.frontend_tokens if cfg.frontend != "none" else 0)

    dispatch = None if args.dispatch == "config" else args.dispatch
    two_phase = None if args.two_phase == "auto" else args.two_phase == "on"
    attn_mask = None
    if args.attn_mask != "none":
        pattern = None if args.attn_mask == "sliding" else args.attn_mask
        attn_mask = AttnMaskSpec(local=True, pattern=pattern,
                                 impl=args.attn_mask_impl)

    if args.continuous:
        rng = np.random.default_rng(0)
        sched = ServeScheduler(
            params, cfg, max_seq=max_seq, max_slots=args.slots,
            dispatch=dispatch, two_phase=two_phase,
            temperature=args.temperature,
            pipeline_depth=args.pipeline_depth,
            quantize_experts=args.quantize_experts,
            kv_quant=args.kv_quant, attn_mask=attn_mask)
        for _ in range(args.requests):
            plen = int(rng.integers(max(2, args.prompt_len // 2),
                                    args.prompt_len + 1))
            sched.submit(rng.integers(0, cfg.vocab_size, plen),
                         int(rng.integers(max(2, args.gen // 2),
                                          args.gen + 1)))
        gen = sched.run()
        s = sched.summary()
        dec = s.get("decode", {"seconds": 0.0, "calls": 0})
        print(f"served {len(gen)} requests in {sched.step_idx} steps "
              f"({dec.get('tok_per_s', 0.0):.1f} decode tok/s)"
              + (" [two-phase]" if sched.two_phase else ""))
        lat = s["token_latency_ms"]
        print(f"per-token latency: p50 {lat['p50']:.1f} ms, "
              f"p99 {lat['p99']:.1f} ms over {lat['n']} tokens")
        print(f"batch buckets: {s['batch_buckets']}"
              + (f"; nnzb buckets: {s['nnzb_buckets']}; "
                 f"{s['compile_signatures']} phase-2 signature(s)"
                 if sched.two_phase else ""))
        if args.pipeline_depth and "timing" in s:
            tm = s["timing"]
            print(f"overlap: {tm['route_hidden_ms']:.1f} ms of route hidden "
                  f"behind in-flight execute "
                  f"({100 * tm['route_hidden_frac']:.0f}% of route)")
        for uid in sorted(gen)[:2]:
            print(f"  [{uid}] {gen[uid][:16].tolist()}")
        return

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    emb = None
    if cfg.frontend != "none":
        emb = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.frontend_tokens, cfg.d_model))

    loop = ServeLoop(
        params, cfg, max_seq=max_seq, dispatch=dispatch, two_phase=two_phase,
        temperature=args.temperature, pipeline_depth=args.pipeline_depth,
        quantize_experts=args.quantize_experts, kv_quant=args.kv_quant,
        attn_mask=attn_mask)
    gen = loop.run(prompts, args.gen, embeddings=emb)
    s = loop.summary()

    pf = s["prefill"]
    print(f"prefill: {pf['seconds']*1e3:.1f} ms for "
          f"{args.batch}x{args.prompt_len}")
    dec = s.get("decode", {"seconds": 0.0, "calls": 0})  # --gen 1: no steps
    print(f"decode:  {dec['seconds']*1e3:.1f} ms for {dec['calls']} steps "
          f"({dec.get('tok_per_s', 0.0):.1f} tok/s)"
          + (" [two-phase]" if loop.two_phase else ""))
    for phase in ("route", "execute"):
        if phase in s:
            print(f"{phase}:   {s[phase]['seconds']*1e3:.1f} ms over "
                  f"{s[phase]['calls']} layer calls (within prefill+decode)")
    if "stream" in s:
        st = s["stream"]
        print(f"stream:  nnzb {st['nnzb_stream_mean']:.1f} (bucketed) vs "
              f"{st['grid_nnzb']} full-grid blocks; "
              f"{s['compile_signatures']} phase-2 compile signature(s)")
    if args.pipeline_depth and "timing" in s:
        tm = s["timing"]
        print(f"overlap: {tm['route_hidden_ms']:.1f} ms of route hidden "
              f"behind in-flight execute "
              f"({100 * tm['route_hidden_frac']:.0f}% of route)")
    print("sample generations (token ids):")
    for b in range(min(args.batch, 2)):
        print(f"  [{b}] {gen[b, :16].tolist()}")


if __name__ == "__main__":
    main()
