"""Serving launcher: a reusable two-phase route-then-compile serving loop.

:class:`ServeLoop` drives prefill -> [route -> execute] -> decode with
per-step stats.  Two modes:

* **fused** (default for gather dispatch) -- the whole one-token decode step
  is one jit-compiled program (`model.decode_step`), the classic serving
  loop.  This is also the mode the old smoke loop ran; greedy (temperature
  0) decoding is token-for-token identical to it.  (With temperature > 0
  the loops differ at the *first* generated token: the old loop always
  argmaxed it, ServeLoop samples every generated token uniformly.)
* **two-phase** (default when the arch has MoE layers and the "bcsr"
  dispatch backend is selected) -- prefill AND each decode step run layer by
  layer (`model.prefill_layered` / `model.decode_step_layered`, every layer
  a cached jit-compiled step); at every attn+moe layer the loop *routes on
  host* (``moe.route_moe``: jitted router matmul, then compacts the dispatch
  matrix to its union nonzero-block stream, padded to a power-of-two nnzb
  bucket) and then calls the jit-compiled expert/combine phase
  (``moe.execute_moe_jit``) on that static-bucketed stream.  Under the old
  single-phase loop, tracing forced the bcsr stream back to the full
  ``E*C x T`` grid -- dense work through the sparse engine; two-phase keeps
  the streamed blocks proportional to what actually routed while recompiles
  stay bounded by the bucket count (see tests/README.md "two-phase serving
  contract").  The only eager seams left in a decode step are the
  intentional host routing yields -- everything else is a cached compiled
  program.

All timings block on device results (``jax.block_until_ready``) before
reading the clock -- async dispatch otherwise makes tok/s meaningless.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --smoke \
      --batch 4 --prompt-len 32 --gen 32
  PYTHONPATH=src python -m repro.launch.serve --arch llama4-scout-17b-a16e \
      --smoke --dispatch bcsr --gen 16
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.models import model as M
from repro.models import moe
from repro.parallel import context as pctx


@dataclasses.dataclass
class StepStat:
    """One timed phase of the loop; ``extra`` carries phase-specific detail
    (e.g. the route phase's nnzb stream accounting)."""
    phase: str          # prefill | route | execute | decode | sample
    step: int           # decode step index (-1 for prefill)
    seconds: float
    tokens: int = 0
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)


class ServeLoop:
    """Batched greedy/temperature serving loop with KV caches.

    Parameters
    ----------
    params, cfg : the model.
    max_seq : static decode-cache capacity (prompt + generation).
    dispatch : MoE dispatch backend override ("gather" | "bcsr");
        default is the config's ``moe_dispatch`` field.
    two_phase : force the route-then-compile decode path on/off; default
        (None) enables it exactly when the arch has attn+moe layers and the
        backend is "bcsr" -- the combination where single-phase jit degrades
        to full-grid streams.
    temperature : 0 = greedy argmax, > 0 = categorical sampling.
    """

    def __init__(self, params, cfg, *, max_seq: int,
                 dispatch: Optional[str] = None,
                 two_phase: Optional[bool] = None,
                 temperature: float = 0.0, sample_seed: int = 3):
        self.params, self.cfg, self.max_seq = params, cfg, max_seq
        self.backend = dispatch or cfg.moe_dispatch
        has_moe = any(k == "attn+moe" for k in cfg.block_unit)
        self.two_phase = ((self.backend == "bcsr" and has_moe)
                          if two_phase is None else two_phase)
        self.temperature = temperature
        self.stats: List[StepStat] = []
        self._exec_keys: set = set()   # distinct phase-2 compile signatures
        self._sample_key = jax.random.PRNGKey(sample_seed)
        self._decode_fused = jax.jit(
            lambda p, c, pos, tok: M.decode_step(p, cfg, c, pos, tok))
        self.cache = None
        self.pos: Optional[int] = None
        self.generated: List[jax.Array] = []

    # ------------------------------------------------------------- phases --

    @contextlib.contextmanager
    def _dispatch_ctx(self):
        """Trace-time backend override for the fused (in-jit) paths.

        Touches ONLY ``MOE_DISPATCH`` -- an ambient ``activation_specs``
        context (mesh, EP/combine layout constraints, dispatch groups) must
        survive into the trace, so this cannot re-enter that manager (which
        resets every global it does not receive)."""
        prev = pctx.MOE_DISPATCH
        pctx.MOE_DISPATCH = self.backend
        try:
            yield
        finally:
            pctx.MOE_DISPATCH = prev

    def prefill(self, prompts: jax.Array,
                embeddings: Optional[jax.Array] = None) -> jax.Array:
        """Run the prompt through the model, fill the decode cache, and
        emit the first generated token (B, 1).

        Resets the generation state up front: the two-phase moe stage
        derives its step label from ``len(self.generated)``, which must
        read -1 (prefill) here even when a previous ``run`` left tokens
        behind.

        In two-phase mode the prompt runs through the *layered* prefill
        (``model.prefill_layered``) with the route->execute stage injected
        at every attn+moe layer, so prefill streams the bucketed routed
        dispatch stream too -- the fused ``model.prefill`` would trace the
        bcsr dispatch back to the full ``E*C x T`` grid (the single-phase
        fallback this loop exists to avoid)."""
        self.generated = []
        t0 = time.monotonic()
        if self.two_phase:
            logits, cache, pos = M.prefill_layered(
                self.params, prompts, self.cfg, max_seq=self.max_seq,
                embeddings=embeddings, moe_fn=self._moe_two_phase)
        else:
            with self._dispatch_ctx():
                logits, cache, pos = M.prefill(self.params, prompts, self.cfg,
                                               max_seq=self.max_seq,
                                               embeddings=embeddings)
        logits, cache = jax.block_until_ready((logits, cache))
        self.stats.append(StepStat(
            "prefill", -1, time.monotonic() - t0,
            tokens=int(np.prod(prompts.shape))))
        self.cache, self.pos = cache, int(pos)
        nxt = self._sample(logits[:, -1])
        self.generated = [nxt]
        return nxt

    def _sample(self, last_logits: jax.Array) -> jax.Array:
        lg = last_logits[:, : self.cfg.vocab_size]
        if self.temperature > 0:
            self._sample_key, k = jax.random.split(self._sample_key)
            nxt = jax.random.categorical(k, lg / self.temperature)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        return nxt[:, None].astype(jnp.int32)

    def _moe_two_phase(self, p_ffn, h, cfg, counts=None, pos=None):
        """The route -> execute stage injected at every attn+moe layer."""
        t0 = time.monotonic()
        h = jax.block_until_ready(h)
        plan, info = moe.route_moe(p_ffn, h, cfg, counts=counts, pos=pos,
                                   dispatch=self.backend)
        step = len(self.generated) - 1
        self.stats.append(StepStat("route", step, time.monotonic() - t0,
                                   tokens=h.shape[0] * h.shape[1],
                                   extra=dict(info)))
        sig = (plan.capacity, plan.backend, tuple(h.shape),
               None if plan.stream is None
               else (plan.stream.nnzb,) + tuple(plan.stream.shape))
        self._exec_keys.add(sig)
        t0 = time.monotonic()
        out, new_counts = moe.execute_moe_jit(p_ffn, h, plan, cfg)
        out = jax.block_until_ready(out)
        self.stats.append(StepStat(
            "execute", step, time.monotonic() - t0,
            tokens=h.shape[0] * h.shape[1],
            extra={"nnzb_stream": info.get("nnzb_stream"),
                   "compile_signatures": len(self._exec_keys)}))
        return out, new_counts

    def decode_step(self) -> jax.Array:
        """Generate one token for every sequence in the batch."""
        if self.cache is None:
            raise RuntimeError("decode_step before prefill")
        step = len(self.generated) - 1
        pos = self.pos + step
        tok = self.generated[-1]
        t0 = time.monotonic()
        if self.two_phase:
            logits, self.cache = M.decode_step_layered(
                self.params, self.cfg, self.cache, pos, tok,
                moe_fn=self._moe_two_phase)
        else:
            with self._dispatch_ctx():
                logits, self.cache = self._decode_fused(
                    self.params, self.cache, jnp.asarray(pos, jnp.int32),
                    tok)
        logits = jax.block_until_ready(logits)
        self.stats.append(StepStat("decode", step, time.monotonic() - t0,
                                   tokens=tok.shape[0]))
        nxt = self._sample(logits[:, -1])
        self.generated.append(nxt)
        return nxt

    def decode(self, n: int):
        for _ in range(n):
            self.decode_step()

    # -------------------------------------------------------------- drive --

    def run(self, prompts: jax.Array, gen: int,
            embeddings: Optional[jax.Array] = None) -> np.ndarray:
        """prefill + (gen - 1) decode steps; returns (B, gen) token ids."""
        self.stats.clear()
        self._exec_keys.clear()
        self.prefill(prompts, embeddings=embeddings)
        self.decode(gen - 1)
        return np.asarray(jnp.concatenate(self.generated, axis=1))

    def summary(self) -> Dict[str, Any]:
        """Aggregate per-phase seconds / counts for the last ``run``.

        Note the phases are NOT disjoint in two-phase mode: each "decode"
        step stat (and the "prefill" stat) times the whole layered pass,
        *inclusive* of the "route" / "execute" layer calls made inside it
        (those entries break the pass down; do not sum them with "decode"
        or "prefill")."""
        out: Dict[str, Any] = {}
        for phase in ("prefill", "route", "execute", "decode"):
            ss = [s for s in self.stats if s.phase == phase]
            if ss:
                out[phase] = {"seconds": sum(s.seconds for s in ss),
                              "calls": len(ss)}
        dec = out.get("decode")
        if dec and dec["seconds"] > 0:
            batch = self.generated[0].shape[0] if self.generated else 0
            out["decode"]["tok_per_s"] = batch * dec["calls"] / dec["seconds"]
        if self.two_phase:
            routes = [s for s in self.stats if s.phase == "route"
                      and "nnzb_stream" in s.extra]
            if routes:
                out["stream"] = {
                    "nnzb_stream_mean": float(np.mean(
                        [s.extra["nnzb_stream"] for s in routes])),
                    "nnzb_routed_mean": float(np.mean(
                        [s.extra["nnzb_routed"] for s in routes])),
                    "grid_nnzb": routes[-1].extra["grid_nnzb"],
                }
            out["compile_signatures"] = len(self._exec_keys)
        return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--dispatch", choices=["config", "gather", "bcsr"],
                    default="config",
                    help="MoE dispatch backend (config = the arch's field)")
    ap.add_argument("--two-phase", choices=["auto", "on", "off"],
                    default="auto",
                    help="route-then-compile decode (auto = when moe+bcsr)")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    max_seq = args.prompt_len + args.gen + (
        cfg.frontend_tokens if cfg.frontend != "none" else 0)

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    emb = None
    if cfg.frontend != "none":
        emb = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.frontend_tokens, cfg.d_model))

    loop = ServeLoop(
        params, cfg, max_seq=max_seq,
        dispatch=None if args.dispatch == "config" else args.dispatch,
        two_phase=None if args.two_phase == "auto" else args.two_phase == "on",
        temperature=args.temperature)
    gen = loop.run(prompts, args.gen, embeddings=emb)
    s = loop.summary()

    pf = s["prefill"]
    print(f"prefill: {pf['seconds']*1e3:.1f} ms for "
          f"{args.batch}x{args.prompt_len}")
    dec = s.get("decode", {"seconds": 0.0, "calls": 0})  # --gen 1: no steps
    print(f"decode:  {dec['seconds']*1e3:.1f} ms for {dec['calls']} steps "
          f"({dec.get('tok_per_s', 0.0):.1f} tok/s)"
          + (" [two-phase]" if loop.two_phase else ""))
    for phase in ("route", "execute"):
        if phase in s:
            print(f"{phase}:   {s[phase]['seconds']*1e3:.1f} ms over "
                  f"{s[phase]['calls']} layer calls (within prefill+decode)")
    if "stream" in s:
        st = s["stream"]
        print(f"stream:  nnzb {st['nnzb_stream_mean']:.1f} (bucketed) vs "
              f"{st['grid_nnzb']} full-grid blocks; "
              f"{s['compile_signatures']} phase-2 compile signature(s)")
    print("sample generations (token ids):")
    for b in range(min(args.batch, 2)):
        print(f"  [{b}] {gen[b, :16].tolist()}")


if __name__ == "__main__":
    main()
