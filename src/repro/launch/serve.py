"""Serving launcher: batched prefill + greedy decode loop with KV caches.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b --smoke \
      --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    max_seq = args.prompt_len + args.gen + (
        cfg.frontend_tokens if cfg.frontend != "none" else 0)

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    emb = None
    if cfg.frontend != "none":
        emb = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.frontend_tokens, cfg.d_model))

    t0 = time.monotonic()
    logits, cache, pos = M.prefill(params, prompts, cfg, max_seq=max_seq,
                                   embeddings=emb)
    nxt = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)[:, None].astype(jnp.int32)
    t_prefill = time.monotonic() - t0

    decode = jax.jit(
        lambda p, c, pos, tok: M.decode_step(p, cfg, c, pos, tok))
    out_tokens = [nxt]
    t0 = time.monotonic()
    sample_key = jax.random.PRNGKey(3)
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, pos + i, nxt)
        lg = logits[:, -1, :cfg.vocab_size]
        if args.temperature > 0:
            sample_key, k = jax.random.split(sample_key)
            nxt = jax.random.categorical(
                k, lg / args.temperature)[:, None].astype(jnp.int32)
        else:
            nxt = jnp.argmax(lg, axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(nxt)
    t_decode = time.monotonic() - t0

    gen = np.asarray(jnp.concatenate(out_tokens, axis=1))
    tps = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"prefill: {t_prefill*1e3:.1f} ms for {args.batch}x{args.prompt_len}")
    print(f"decode:  {t_decode*1e3:.1f} ms for {args.gen-1} steps "
          f"({tps:.1f} tok/s)")
    print("sample generations (token ids):")
    for b in range(min(args.batch, 2)):
        print(f"  [{b}] {gen[b, :16].tolist()}")


if __name__ == "__main__":
    main()
