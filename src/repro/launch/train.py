"""Training launcher: end-to-end driver over the fault-tolerant runtime.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
      --steps 50 --batch 8 --seq 128
  PYTHONPATH=src python -m repro.launch.train --arch llama4-scout-17b-a16e \
      --smoke --steps 20 --grad-compress-k 4096
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke
from repro.data.pipeline import SyntheticLM
from repro.grad_comp.sparse_allreduce import compress, union_reduce
from repro.core.su import stream_densify
from repro.models import model as M
from repro.optim.adamw import AdamW, cosine_schedule, global_norm
from repro.runtime.trainer import Trainer, TrainerConfig


def make_step(cfg, opt, grad_compress_k: int = 0):
    @jax.jit
    def step(params, opt_state, tokens, embeddings=None):
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(p, tokens, cfg, embeddings=embeddings))(params)
        if grad_compress_k:
            # top-k sparse gradient exchange (SU union) on every large leaf;
            # single-host sim: compress+densify (lossy path exercised e2e)
            def comp(g):
                if g.size <= grad_compress_k:
                    return g
                keys, vals, _ = compress(g.reshape(-1), grad_compress_k)
                return stream_densify(keys, vals,
                                      jnp.asarray(grad_compress_k),
                                      g.size).reshape(g.shape)
            grads = jax.tree.map(comp, grads)
        new_p, new_o = opt.update(grads, opt_state, params)
        return new_p, new_o, {"loss": loss, "grad_norm": global_norm(grads)}
    return step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="checkpoints/train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--grad-compress-k", type=int, default=0)
    ap.add_argument("--policy", default=None, help="f32|bf16|fp8_e4m3")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if args.policy:
        cfg = dataclasses.replace(cfg, policy=args.policy)
    opt = AdamW(lr=cosine_schedule(args.lr, warmup=min(20, args.steps // 5),
                                   total=args.steps))
    data = SyntheticLM(cfg, batch=args.batch, seq_len=args.seq, seed=0)
    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir, log_every=5),
        cfg, make_step(cfg, opt, args.grad_compress_k), opt, data,
        init_state=lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    out = trainer.run()
    first = out["history"][0][1]
    last = out["history"][-1][1]
    print(f"done: loss {first:.4f} -> {last:.4f} over {args.steps} steps; "
          f"stragglers={len(out['stragglers'])}")


if __name__ == "__main__":
    main()
