"""Step factories: train_step / prefill_step / serve_step with shardings.

These are what the dry-run lowers and what train.py/serve.py execute. Each
factory returns (step_fn, in_specs, out_specs) where the spec trees mirror
the abstract inputs/outputs (PartitionSpec leaves).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import model as M
from repro.models.config import ArchConfig
from repro.optim.adamw import AdamW, AdamWState, cosine_schedule, global_norm
from repro.parallel import context as pctx
from repro.parallel import sharding as S
from repro.launch.shapes import ShapeSpec, token_inputs


def default_optimizer(total_steps: int = 10000,
                      master_weights: bool = False) -> AdamW:
    return AdamW(lr=cosine_schedule(3e-4, 200, total_steps),
                 master_weights=master_weights)


def cast_params_bf16(params_tree):
    """Model params in bf16 (>=2-D leaves); norms/bias vectors stay f32."""
    return jax.tree.map(
        lambda x: (jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
                   if isinstance(x, jax.ShapeDtypeStruct) and x.ndim >= 2
                   and x.dtype == jnp.float32 else
                   x.astype(jnp.bfloat16)
                   if not isinstance(x, jax.ShapeDtypeStruct) and x.ndim >= 2
                   and x.dtype == jnp.float32 else x),
        params_tree)


def _dp_axis(mesh):
    return S._filter(P(S.FSDP), mesh)[0]


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(lambda k: M.init_params(k, cfg),
                          jax.random.PRNGKey(0))


def abstract_opt_state(cfg: ArchConfig, optimizer: AdamW):
    params = abstract_params(cfg)
    return jax.eval_shape(optimizer.init, params)


# ------------------------------------------------------------------ train ---

def make_train_step(cfg: ArchConfig, shape: ShapeSpec, mesh,
                    optimizer: Optional[AdamW] = None, *,
                    seq_chunk: int = 512, impl: str = "chunked",
                    seq_parallel: bool = True, moe_impl: str = "pjit",
                    moe_dispatch: Optional[str] = None,
                    microbatches: Optional[int] = None,
                    attn_impl: Optional[str] = None):
    """Returns (train_step, (in_shardings...), (out_shardings...)).

    ``seq_parallel``: shard the residual stream's sequence dim over "model"
    (Megatron-SP). The remat-saved per-layer carries shrink by the TP width,
    which is what keeps the 4k x 256 train cells inside HBM; GSPMD inserts
    the all-gathers around attention/MLP that TP needs anyway.
    """
    optimizer = optimizer or default_optimizer()
    dp = _dp_axis(mesh)
    k = microbatches or shape.microbatches
    act_spec = P(dp, "model", None) if seq_parallel else P(dp, None, None)
    moe_spec = P("model", dp, None, None) if cfg.n_experts else None
    moe_combine = P(dp, None, None) if cfg.n_experts else None
    moe_groups = S.data_axis_size(mesh) if cfg.n_experts else None
    logit_spec = P(dp, None, "model")

    def train_step(params, opt_state, tokens, embeddings=None):
        def loss_of(p, tok, emb):
            with pctx.activation_specs(act=act_spec, moe=moe_spec,
                                       logit=logit_spec, moe_groups=moe_groups,
                                       moe_combine=moe_combine,
                                       moe_impl=moe_impl,
                                       moe_dispatch=moe_dispatch, mesh=mesh):
                return M.loss_fn(p, tok, cfg, embeddings=emb,
                                 impl=attn_impl or impl, seq_chunk=seq_chunk)

        if k == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, tokens, embeddings)
        else:
            B = tokens.shape[0]
            tok_mb = tokens.reshape(k, B // k, tokens.shape[1])
            emb_mb = (embeddings.reshape(k, B // k, *embeddings.shape[1:])
                      if embeddings is not None else None)

            def mb_body(carry, inp):
                loss_acc, grad_acc = carry
                tok = inp[0]
                emb = inp[1] if emb_mb is not None else None
                l, g = jax.value_and_grad(loss_of)(params, tok, emb)
                grad_acc = jax.tree.map(jnp.add, grad_acc, g)
                return (loss_acc + l, grad_acc), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            xs = (tok_mb,) if emb_mb is None else (tok_mb, emb_mb)
            (loss, grads), _ = jax.lax.scan(
                mb_body, (jnp.zeros(()), zeros), xs)
            loss = loss / k
            grads = jax.tree.map(lambda g: g / k, grads)

        gn = global_norm(grads)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, {"loss": loss, "grad_norm": gn}

    p_specs = S.param_specs(abstract_params(cfg), mesh)
    o_specs = AdamWState(m=p_specs, v=p_specs, count=P(),
                         master=(p_specs if optimizer.master_weights else None))
    tok_spec = P(dp, None)
    emb_spec = P(dp, None, None)
    return train_step, (p_specs, o_specs, tok_spec, emb_spec), \
        (p_specs, o_specs, {"loss": P(), "grad_norm": P()})


# ---------------------------------------------------------------- serving ---

def make_prefill_step(cfg: ArchConfig, shape: ShapeSpec, mesh, *,
                      impl: str = "chunked", seq_parallel: bool = True,
                      moe_impl: str = "pjit",
                      moe_dispatch: Optional[str] = None):
    if cfg.n_experts and moe_impl == "shard_map":
        import warnings
        warnings.warn(
            "make_prefill_step(moe_impl='shard_map'): the shard_map MoE impl "
            "is train-only and cannot fill the decode cache's routing "
            "occupancy, so a subsequent decode would see a different MoE "
            "drop set than this prefill. Serve with the pjit impl.",
            RuntimeWarning, stacklevel=2)
    dp = _dp_axis(mesh)
    act_spec = P(dp, "model", None) if seq_parallel else P(dp, None, None)
    moe_spec = P("model", dp, None, None) if cfg.n_experts else None
    moe_combine = P(dp, None, None) if cfg.n_experts else None
    moe_groups = S.data_axis_size(mesh) if cfg.n_experts else None

    def prefill_step(params, tokens, embeddings=None):
        with pctx.activation_specs(act=act_spec, moe=moe_spec,
                                   moe_groups=moe_groups,
                                   moe_combine=moe_combine, moe_impl=moe_impl,
                                   moe_dispatch=moe_dispatch, mesh=mesh):
            return M.prefill(params, tokens, cfg, max_seq=shape.seq_len,
                             embeddings=embeddings, impl=impl)

    p_specs = S.param_specs(abstract_params(cfg), mesh)
    cache = abstract_cache(cfg, shape)
    c_specs = S.cache_specs(cache, cfg, mesh, batch=shape.global_batch)
    out_specs = (P(dp, None, "model"), c_specs, P())
    return prefill_step, (p_specs, P(dp, None), P(dp, None, None)), out_specs


def make_serve_step(cfg: ArchConfig, shape: ShapeSpec, mesh, *,
                    greedy: bool = True,
                    moe_dispatch: Optional[str] = None):
    """One-token decode + greedy sampling."""
    dp = _dp_axis(mesh)
    moe_spec = P("model", dp, None, None) if cfg.n_experts else None
    moe_combine = P(dp, None, None) if cfg.n_experts else None
    moe_groups = S.data_axis_size(mesh) if cfg.n_experts else None

    def serve_step(params, cache, pos, tokens_1):
        with pctx.activation_specs(moe=moe_spec, moe_groups=moe_groups,
                                   moe_combine=moe_combine,
                                   moe_dispatch=moe_dispatch):
            logits, new_cache = M.decode_step(params, cfg, cache, pos, tokens_1)
        nxt = jnp.argmax(logits[:, -1, : cfg.vocab_size],
                         axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, new_cache

    p_specs = S.param_specs(abstract_params(cfg), mesh)
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len))
    c_specs = S.cache_specs(cache, cfg, mesh, batch=shape.global_batch)
    batch_ok = shape.global_batch % S.data_axis_size(mesh) == 0 and \
        shape.global_batch >= S.data_axis_size(mesh)
    tok_spec = P(dp, None) if batch_ok else P(None, None)
    return serve_step, (p_specs, c_specs, P(), tok_spec), \
        (tok_spec, None, c_specs)


def abstract_cache(cfg: ArchConfig, shape: ShapeSpec):
    return jax.eval_shape(
        lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len))
