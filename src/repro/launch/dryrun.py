import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax-importing module: jax locks the
# device count at first backend init. Placeholder host devices exist ONLY in
# this dry-run entrypoint; tests/benches see the single real CPU device.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Per cell:
  * builds the step function (train/prefill/serve) with production shardings,
  * ``.lower().compile()`` against ShapeDtypeStruct inputs (no allocation),
  * records memory_analysis / cost_analysis / loop-aware HLO accounting
    (FLOPs, HBM-traffic proxy, per-op collective bytes) as one JSON file.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --list-cells
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config
from repro.launch import steps as St
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh, mesh_context
from repro.launch.shapes import SHAPES, cell_is_runnable, token_inputs
from repro.parallel import sharding as Sh

# v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _with_sharding(structs, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        structs, shardings,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


# --variant opt: the SPerf-optimized configuration (per-cell knobs)
OPT_MICROBATCHES = {  # train_4k cells that exceed HBM at microbatch=1
    "nemotron-4-340b": 1,
    "llama4-maverick-400b-a17b": 4,
    "llama4-scout-17b-a16e": 4,
    "gemma3-12b": 4,
    "zamba2-1.2b": 2,
    "internvl2-26b": 2,
}


def build_cell(arch: str, shape_name: str, mesh, variant: str = "base"):
    """Returns (jitted, abstract_args) for the cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        opt = St.default_optimizer(master_weights=(variant == "opt"))
        kw = {}
        if variant == "opt":
            if cfg.n_experts:
                kw["moe_impl"] = "shard_map"
            kw["microbatches"] = (OPT_MICROBATCHES.get(arch, 1)
                                  if shape_name == "train_4k" else 1)
            kw["attn_impl"] = "kernel_sharded"
        step, (p_s, o_s, tok_s, emb_s), out_s = St.make_train_step(
            cfg, shape, mesh, opt, **kw)
        abs_params = St.abstract_params(cfg)
        if variant == "opt":
            abs_params = St.cast_params_bf16(abs_params)
        params = _with_sharding(abs_params, _ns(mesh, p_s))
        abs_opt = jax.eval_shape(opt.init, abs_params)
        opt_state = _with_sharding(abs_opt, _ns(mesh, o_s))
        tokens, emb = token_inputs(cfg, shape)
        tokens = jax.ShapeDtypeStruct(tokens.shape, tokens.dtype,
                                      sharding=NamedSharding(mesh, tok_s))
        args = [params, opt_state, tokens]
        out_shardings = (_ns(mesh, out_s[0]), _ns(mesh, out_s[1]),
                         _ns(mesh, out_s[2]))
        if emb is not None:
            args.append(jax.ShapeDtypeStruct(
                emb.shape, emb.dtype, sharding=NamedSharding(mesh, emb_s)))
        jitted = jax.jit(step, out_shardings=out_shardings,
                         donate_argnums=(0, 1))
        return jitted, args, cfg, shape

    if shape.kind == "prefill":
        kw = {}
        if variant == "opt":
            kw["impl"] = "kernel_sharded"
            if cfg.n_experts:
                kw["moe_impl"] = "shard_map"
        step, (p_s, tok_s, emb_s), out_s = St.make_prefill_step(
            cfg, shape, mesh, **kw)
        params = _with_sharding(St.abstract_params(cfg), _ns(mesh, p_s))
        tokens, emb = token_inputs(cfg, shape)
        tokens = jax.ShapeDtypeStruct(tokens.shape, tokens.dtype,
                                      sharding=NamedSharding(mesh, tok_s))
        args = [params, tokens]
        if emb is not None:
            args.append(jax.ShapeDtypeStruct(
                emb.shape, emb.dtype, sharding=NamedSharding(mesh, emb_s)))
        out_shardings = (_ns(mesh, out_s[0]), _ns(mesh, out_s[1]),
                         NamedSharding(mesh, out_s[2]))
        return jax.jit(step, out_shardings=out_shardings), args, cfg, shape

    # decode
    step, (p_s, c_s, pos_s, tok_s), out_s = St.make_serve_step(cfg, shape, mesh)
    params = _with_sharding(St.abstract_params(cfg), _ns(mesh, p_s))
    cache = _with_sharding(St.abstract_cache(cfg, shape), _ns(mesh, c_s))
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))
    tokens_1 = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32,
                                    sharding=NamedSharding(mesh, tok_s))
    out_shardings = (_ns(mesh, out_s[0]), None, _ns(mesh, out_s[2]))
    jitted = jax.jit(step, out_shardings=out_shardings, donate_argnums=(1,))
    return jitted, [params, cache, pos, tokens_1], cfg, shape


def model_flops(cfg, shape) -> float:
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch * 1  # decode: one token per sequence


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             save_hlo: bool = False, variant: str = "base") -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.size
    with mesh_context(mesh):
        jitted, args, cfg, shape = build_cell(arch, shape_name, mesh, variant)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    acc = analyze(hlo)

    coll = acc["collective_bytes_total"]
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "devices": n_dev,
        "variant": variant,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_per_device": ma.argument_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes
            + ma.output_size_in_bytes,
        },
        "cost_analysis": {"flops_body_once": ca.get("flops", 0.0),
                          "bytes_body_once": ca.get("bytes accessed", 0.0)},
        "hlo": {k: acc[k] for k in ("dot_flops", "collective_bytes",
                                    "collective_bytes_total",
                                    "collective_bytes_tpu_corrected",
                                    "traffic_bytes", "n_computations")},
        "op_hist": acc["op_hist"],
        "roofline": {
            "compute_s": acc["dot_flops"] / PEAK_FLOPS_BF16,
            "memory_s": acc["traffic_bytes"] / HBM_BW,
            "collective_s": coll / ICI_BW,
        },
        "model_flops_total": model_flops(cfg, shape),
        "model_flops_per_device": model_flops(cfg, shape) / n_dev,
    }
    r = rec["roofline"]
    dom = max(r, key=r.get)
    rec["roofline"]["dominant"] = dom
    rec["roofline"]["collective_s_tpu_corrected"] = (
        acc["collective_bytes_tpu_corrected"] / ICI_BW)
    rec["model_vs_hlo_flops"] = (rec["model_flops_per_device"]
                                 / max(acc["dot_flops"], 1.0))
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = "" if variant == "base" else f"__{variant}"
    name = f"{arch}__{shape_name}__{mesh_kind}{suffix}"
    (out_dir / f"{name}.json").write_text(json.dumps(rec, indent=1))
    if save_hlo:
        (out_dir / f"{name}.hlo.txt").write_text(hlo)
    return rec


def list_cells():
    cells = []
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            if cell_is_runnable(cfg, shape):
                cells.append((arch, sname))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--variant", default="base", choices=["base", "opt"])
    ap.add_argument("--list-cells", action="store_true")
    args = ap.parse_args()
    if args.list_cells:
        for arch, sname in list_cells():
            print(f"{arch} {sname}")
        return
    assert args.arch and args.shape
    try:
        rec = run_cell(args.arch, args.shape, args.mesh, Path(args.out),
                       save_hlo=args.save_hlo, variant=args.variant)
        r = rec["roofline"]
        print(f"OK {args.arch} {args.shape} {args.mesh} [{args.variant}]: "
              f"compile={rec['compile_s']}s "
              f"peak/dev={rec['memory']['peak_per_device']/2**30:.2f}GiB "
              f"compute={r['compute_s']*1e3:.2f}ms "
              f"memory={r['memory_s']*1e3:.2f}ms "
              f"collective={r['collective_s']*1e3:.2f}ms "
              f"dominant={r['dominant']}")
    except Exception:
        print(f"FAIL {args.arch} {args.shape} {args.mesh}")
        traceback.print_exc()
        raise SystemExit(1)


if __name__ == "__main__":
    main()
