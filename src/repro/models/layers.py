"""Neural-net layer primitives: norms, RoPE, GQA attention, MLPs.

Pure-function style: ``init_*`` builds a param dict, ``apply_*`` consumes it.
Attention has three interchangeable implementations with one contract:

* ``kernel``  -- the Pallas flash kernel (TPU target; interpret-tested on CPU)
* ``chunked`` -- pure-jnp online-softmax over KV chunks: identical memory
                 profile to the kernel (no (S,S) materialization), lowerable on
                 any backend -- this is what the multi-pod dry-run rooflines.
* ``ref``     -- materialized softmax oracle.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.masks import NEG_INF, AttnMaskSpec
from repro.models.config import ArchConfig


# ----------------------------------------------------------------- norms ----

def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * p["scale"]).astype(x.dtype)


# ------------------------------------------------------------------ rope ----

def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, hd); positions: (S,) or broadcastable."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------- attention ----

_FLASH_CVJP_CACHE = {}


def flash_fwd_chunked_bwd(causal: bool, window):
    """Differentiable kernelized attention: the Pallas flash kernel on the
    forward (streaming memory profile), the chunked-jnp VJP on the backward
    (per-chunk remat; the flash backward kernel is future work). This is what
    lets *train* steps run the kernel forward (SPerf-E)."""
    key = (causal, window)
    if key in _FLASH_CVJP_CACHE:
        return _FLASH_CVJP_CACHE[key]

    @jax.custom_vjp
    def f(q, k, v):
        return sharded_flash_attention(q, k, v, causal=causal, window=window)

    def fwd(q, k, v):
        return f(q, k, v), (q, k, v)

    def bwd(res, g):
        q, k, v = res
        _, vjp = jax.vjp(
            lambda q_, k_, v_: chunked_attention(q_, k_, v_, causal=causal,
                                                 window=window), q, k, v)
        return vjp(g)

    f.defvjp(fwd, bwd)
    _FLASH_CVJP_CACHE[key] = f
    return f


def sharded_flash_attention(q, k, v, *, causal=True, window=None):
    """Pallas flash kernel under shard_map: q sequence-sharded over "model",
    batch over the FSDP axes; K/V gathered per shard (the gather SP performs
    anyway). Scores never leave VMEM -- the SPerf-D lever for prefill.

    Inference-only (the kernel has no custom VJP); the train path keeps the
    differentiable chunked formulation.
    """
    from repro.parallel import context as pctx
    from repro.parallel.sharding import FSDP
    from repro.kernels.flash_attention.kernel import flash_attention as _fk
    mesh = pctx.MESH
    if mesh is None:
        from repro.kernels.flash_attention.ops import attention as flash
        return flash(q, k, v, causal=causal, window=window)
    from jax.sharding import PartitionSpec as P
    dp = tuple(a for a in FSDP if a in mesh.axis_names)
    dp = dp if len(dp) > 1 else dp[0]
    tp = "model"
    S = q.shape[2]
    S_loc = S // mesh.shape[tp]
    interpret = jax.devices()[0].platform != "tpu"

    def body(qb, kb, vb):
        off = jax.lax.axis_index(tp) * S_loc
        return _fk(qb, kb, vb, causal=causal, window=window, q_offset=off,
                   bq=min(128, S_loc), bk=128, interpret=interpret)

    from repro.parallel.sharding import compat_shard_map
    fn = compat_shard_map(
        body, mesh=mesh,
        in_specs=(P(dp, None, tp, None), P(dp, None, None, None),
                  P(dp, None, None, None)),
        out_specs=P(dp, None, tp, None),
        check=False)  # pallas_call outputs carry no replication/vma metadata
    return fn(q, k, v)


def init_attention(key, cfg: ArchConfig):
    d, hd, Hq, Hkv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": jax.random.normal(k1, (d, Hq * hd), jnp.float32) * s,
        "wk": jax.random.normal(k2, (d, Hkv * hd), jnp.float32) * s,
        "wv": jax.random.normal(k3, (d, Hkv * hd), jnp.float32) * s,
        "wo": jax.random.normal(k4, (Hq * hd, d), jnp.float32) * s,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hq * hd,), jnp.float32)
        p["bk"] = jnp.zeros((Hkv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((Hkv * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd)
        p["k_norm"] = init_rmsnorm(hd)
    return p


def _qkv(p, x, cfg: ArchConfig, positions):
    B, S, d = x.shape
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    cd = x.dtype
    q = x @ p["wq"].astype(cd)
    k = x @ p["wk"].astype(cd)
    v = x @ p["wv"].astype(cd)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    q = q.reshape(B, S, Hq, hd).transpose(0, 2, 1, 3)
    k = k.reshape(B, S, Hkv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, Hkv, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def chunked_attention(q, k, v, *, causal=True, window=None, chunk=1024):
    """Online-softmax over KV chunks in pure jnp (flash semantics, XLA-fused).

    q: (B, Hq, Sq, hd); k/v: (B, Hkv, Skv, hd).

    Occamy-style multi-precision discipline: operands stream in their narrow
    dtype (bf16) and only the MXU accumulators widen to f32 (the ExSdotp
    pattern) -- no f32 K/V buffers, no materialized GQA head repeat. This
    halves HBM and collective traffic vs. the naive formulation (measured in
    EXPERIMENTS.md SPerf).
    """
    B, Hq, Sq, hd = q.shape
    _, Hkv, Skv, _ = k.shape
    g = Hq // Hkv
    scale = hd ** -0.5
    chunk = min(chunk, Skv)
    pad = (-Skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    n_chunks = (Skv + pad) // chunk
    kc = k.reshape(B, Hkv, n_chunks, chunk, hd).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, Hkv, n_chunks, chunk, hd).transpose(2, 0, 1, 3, 4)
    # GQA without repeat: group dim g rides along the head dim of q only
    qg = (q * scale).astype(k.dtype).reshape(B, Hkv, g, Sq, hd)
    q_pos = jnp.arange(Sq)[:, None]

    def body(carry, inp):
        m, l, acc, ci = carry
        kb, vb = inp                                      # (B, Hkv, chunk, hd)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kb,
                       preferred_element_type=jnp.float32)
        k_pos = ci * chunk + jnp.arange(chunk)[None, :]
        mask = k_pos < Skv
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc, ci + 1), None

    init = (jnp.full((B, Hkv, g, Sq, 1), NEG_INF, jnp.float32),
            jnp.zeros((B, Hkv, g, Sq, 1), jnp.float32),
            jnp.zeros((B, Hkv, g, Sq, hd), jnp.float32),
            jnp.asarray(0, jnp.int32))
    # flash backward = recompute: without this, AD stacks per-chunk scores/
    # probs across ALL chunks (n_chunks x (B,H,Sq,chunk) f32 residuals)
    body = jax.checkpoint(body)
    (m, l, acc, _), _ = jax.lax.scan(body, init, (kc, vc))
    out = acc / jnp.where(l == 0, 1.0, l)
    return out.reshape(B, Hq, Sq, hd).astype(q.dtype)


def _masked_prefill_attention(q, k, v, spec: AttnMaskSpec, window):
    """Prefill through the block-sparse stream walk when the AttnMaskSpec
    applies to this layer (sliding-window layers via ``spec.local``,
    full-attention layers via ``spec.pattern``); None -> caller falls back
    to the dense impl dispatch.  Mask construction is host numpy on static
    shapes, so it runs once per trace and the lowered stream becomes a
    compile-time operand (recompiles keyed on pattern signature x bucket).
    """
    from repro.kernels import tuning
    from repro.kernels.flash_attention import ops as fops
    S, D = q.shape[2], q.shape[3]
    pattern = "window" if window is not None else spec.pattern
    bq, bk = spec.bq, spec.bk
    if bq is None or bk is None:
        tbq, tbk = tuning.flash_sparse_tiles(S, S, D, q.dtype,
                                             pattern=pattern)
        bq, bk = bq or tbq, bk or tbk
    mask = spec.build(S, S, layer_window=window, bq=bq, bk=bk)
    if mask is None:
        return None
    return fops.attention(q, k, v, mask=mask, mask_impl=spec.impl,
                          interpret=not tuning.on_tpu())


def apply_attention(p, x, cfg: ArchConfig, *, window=None, positions=None,
                    impl: str = "chunked", cache=None, cache_len=None,
                    collect_kv: int = 0, kv_quant: Optional[str] = None,
                    attn_mask: Optional[AttnMaskSpec] = None):
    """Self-attention (train/prefill) or one-step decode when ``cache`` given.

    cache: dict(k=(B,Hkv,S,hd), v=...) -- updated functionally; ``cache_len``
    is the current fill: an int32 scalar (whole-batch decode, every row at
    the same position) or an int32 ``(B,)`` vector (continuous batching,
    every row at its own position -- the write becomes a per-row scatter and
    RoPE/masking use per-row positions; per row the arithmetic is identical
    to the scalar path at that row's position).
    ``collect_kv``: when > 0 (prefill), also return a fresh KV cache of that
    capacity filled with this call's keys/values (window-truncated for local
    layers).
    ``kv_quant``: narrow dtype name ("fp8_e4m3"/"fp8_e5m2"/"int8") to store
    the collected cache as per-position BlockQuant values (``k``/``v``
    narrow + ``k_scale``/``v_scale`` f32 over head_dim).  Only applies to
    full-context layers (``window is None``) -- local ring buffers stay
    wide.  Decode auto-detects a quantized cache by its ``k_scale`` leaf:
    new keys/values are quantized per position before the scatter and the
    whole cache is dequantized to the query dtype before attention.
    ``attn_mask``: an ``AttnMaskSpec`` routes prefill through the
    block-sparse stream-walk kernel (sliding-window layers and/or an opt-in
    long-context pattern); decode is untouched.
    Returns (out, new_cache).
    """
    B, S, d = x.shape
    if cache is None:
        positions = positions if positions is not None else jnp.arange(S)
        q, k, v = _qkv(p, x, cfg, positions)
        out = None
        if attn_mask is not None:
            out = _masked_prefill_attention(q, k, v, attn_mask, window)
        if out is not None:
            pass
        elif impl == "kernel":
            from repro.kernels.flash_attention.ops import attention as flash
            out = flash(q, k, v, causal=True, window=window)
        elif impl == "kernel_sharded":
            out = flash_fwd_chunked_bwd(True, window)(q, k, v)
        elif impl == "chunked":
            out = chunked_attention(q, k, v, causal=True, window=window)
        else:
            from repro.kernels.flash_attention.ref import attention_ref
            out = attention_ref(q, k, v, causal=True, window=window)
        new_cache = None
        if collect_kv:
            cap = min(collect_kv, window) if window else collect_kv
            if window and S >= window:
                # local-layer ring buffer: keep the last `window` positions,
                # placed at their ring slots (pos % window)
                order = jnp.argsort(positions[-window:] % window)
                kc = jnp.take(k[:, :, -window:], order, axis=2)
                vc = jnp.take(v[:, :, -window:], order, axis=2)
            else:
                pad = cap - S
                kc = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
                vc = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
            if kv_quant is not None and not window:
                from repro.core import precision
                qk, sk = precision.quantize_rows(kc, kv_quant)
                qv, sv = precision.quantize_rows(vc, kv_quant)
                new_cache = {"k": qk, "k_scale": sk, "v": qv, "v_scale": sv}
            else:
                new_cache = {"k": kc, "v": vc}
    else:
        assert S == 1
        quant = "k_scale" in cache
        if quant:
            from repro.core import precision
            qname = precision.quant_name(cache["k"].dtype)
        pos = jnp.asarray(cache_len)
        if pos.ndim:  # per-row fill pointers (continuous batching)
            pos = pos.reshape(-1).astype(jnp.int32)
            q, k1, v1 = _qkv(p, x, cfg, pos[:, None, None])
            b_idx = jnp.arange(B)
            if quant:
                qk1, sk1 = precision.quantize_rows(k1[:, :, 0], qname)
                qv1, sv1 = precision.quantize_rows(v1[:, :, 0], qname)
                kc = cache["k"].at[b_idx, :, pos].set(qk1)
                vc = cache["v"].at[b_idx, :, pos].set(qv1)
                ks = cache["k_scale"].at[b_idx, :, pos].set(sk1)
                vs = cache["v_scale"].at[b_idx, :, pos].set(sv1)
            else:
                kc = cache["k"].at[b_idx, :, pos].set(
                    k1[:, :, 0].astype(cache["k"].dtype))
                vc = cache["v"].at[b_idx, :, pos].set(
                    v1[:, :, 0].astype(cache["v"].dtype))
        else:
            pos = pos.reshape(())  # scalar fill pointer
            q, k1, v1 = _qkv(p, x, cfg, jnp.full((1,), pos))
            if quant:
                qk1, sk1 = precision.quantize_rows(k1, qname)
                qv1, sv1 = precision.quantize_rows(v1, qname)
                kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], qk1, pos, axis=2)
                vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], qv1, pos, axis=2)
                ks = jax.lax.dynamic_update_slice_in_dim(cache["k_scale"], sk1, pos, axis=2)
                vs = jax.lax.dynamic_update_slice_in_dim(cache["v_scale"], sv1, pos, axis=2)
            else:
                kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k1.astype(cache["k"].dtype), pos, axis=2)
                vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v1.astype(cache["v"].dtype), pos, axis=2)
        from repro.kernels.flash_attention.ops import decode_attention
        if quant:
            out = decode_attention(q, precision.dequantize_rows(kc, ks, q.dtype),
                                   precision.dequantize_rows(vc, vs, q.dtype),
                                   kv_len=pos + 1, window=window)
            new_cache = {"k": kc, "k_scale": ks, "v": vc, "v_scale": vs}
        else:
            out = decode_attention(q, kc, vc, kv_len=pos + 1, window=window)
            new_cache = {"k": kc, "v": vc}
    out = out.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.hd)
    return out @ p["wo"].astype(out.dtype), new_cache


# ------------------------------------------------------------------- mlp ----

def init_mlp(key, cfg: ArchConfig, d_ff: Optional[int] = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    s = d ** -0.5
    if cfg.mlp_type == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {"w_gate": jax.random.normal(k1, (d, ff), jnp.float32) * s,
                "w_up": jax.random.normal(k2, (d, ff), jnp.float32) * s,
                "w_down": jax.random.normal(k3, (ff, d), jnp.float32) * (ff ** -0.5)}
    k1, k2 = jax.random.split(key)
    return {"w_up": jax.random.normal(k1, (d, ff), jnp.float32) * s,
            "w_down": jax.random.normal(k2, (ff, d), jnp.float32) * (ff ** -0.5)}


def apply_mlp(p, x, cfg: ArchConfig):
    cd = x.dtype
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"].astype(cd)) * (x @ p["w_up"].astype(cd))
    else:  # squared_relu (Nemotron-4)
        h = jnp.square(jax.nn.relu(x @ p["w_up"].astype(cd)))
    return h @ p["w_down"].astype(cd)
