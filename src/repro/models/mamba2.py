"""Mamba-2 mixer (SSD, chunked) -- Zamba-2's backbone layer.

Streaming view (the Occamy lens): the SSD recurrence
``h_t = a_t * h_{t-1} + dt_t * x_t B_t^T`` is an affine stream over time with
a data-dependent decay; the chunked algorithm below turns it into dense tile
work (intra-chunk quadratic + inter-chunk scan), which is exactly the
re-blocking-for-the-MXU discipline used everywhere in this repo.

Shapes: x (B, T, d); d_in = expand*d; nh = d_in/ssm_head_dim heads; state ns.
``mamba_scan_ref`` is the naive sequential oracle used by the tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import init_rmsnorm, rmsnorm


def init_mamba(key, cfg: ArchConfig):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    ns, hd = cfg.ssm_state, cfg.ssm_head_dim
    nh = d_in // hd
    conv_ch = d_in + 2 * ns
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        # fused input projection: [x(d_in), B(ns), C(ns), z(d_in), dt(nh)]
        "w_in": jax.random.normal(ks[0], (d, 2 * d_in + 2 * ns + nh), jnp.float32) * s,
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": init_rmsnorm(d_in),
        "w_out": jax.random.normal(ks[2], (d_in, d), jnp.float32) * (d_in ** -0.5),
    }


def _split_proj(p, x, cfg: ArchConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    ns = cfg.ssm_state
    nh = d_in // cfg.ssm_head_dim
    proj = x @ p["w_in"].astype(x.dtype)
    xs, Bv, Cv, z, dt = jnp.split(
        proj, [d_in, d_in + ns, d_in + 2 * ns, 2 * d_in + 2 * ns], axis=-1)
    return xs, Bv, Cv, z, dt, d_in, ns, nh


def _causal_conv(xBC, w, b, prev=None):
    """Depthwise causal conv over time. xBC: (B, T, C); w: (K, C).

    ``prev``: (B, K-1, C) carry-in for decode; returns (out, new_prev)."""
    K = w.shape[0]
    if prev is None:
        prev = jnp.zeros((xBC.shape[0], K - 1, xBC.shape[2]), xBC.dtype)
    full = jnp.concatenate([prev, xBC], axis=1)
    out = sum(full[:, i : i + xBC.shape[1]] * w[i] for i in range(K)) + b
    return jax.nn.silu(out), full[:, -(K - 1):]


def ssd_chunked(xh, a_log, Bv, Cv, *, chunk: int = 64, h0=None):
    """Chunked SSD. xh: (B,T,nh,hd) (already dt-scaled); a_log: (B,T,nh) (<=0);
    Bv/Cv: (B,T,ns). Returns (y (B,T,nh,hd), h_final (B,nh,hd,ns))."""
    B, T, nh, hd = xh.shape
    ns = Bv.shape[-1]
    pad = (-T) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        Bv = jnp.pad(Bv, ((0, 0), (0, pad), (0, 0)))
        Cv = jnp.pad(Cv, ((0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    nc = Tp // chunk
    xc = xh.reshape(B, nc, chunk, nh, hd)
    ac = a_log.reshape(B, nc, chunk, nh)
    Bc = Bv.reshape(B, nc, chunk, ns)
    Cc = Cv.reshape(B, nc, chunk, ns)

    cum = jnp.cumsum(ac, axis=2)                         # inclusive within chunk
    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for i>=j (j contributes at i>=j)
    # NB: mask BEFORE exp -- the i<j region has positive exponents that
    # overflow, and where-after-exp poisons gradients with NaNs.
    li = cum[:, :, :, None, :]                           # (B,nc,Q,1,nh)
    lj = cum[:, :, None, :, :]                           # (B,nc,1,Q,nh)
    mask = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])
    L = jnp.exp(jnp.where(mask[None, None, :, :, None], li - lj, -jnp.inf))
    scores = jnp.einsum("bcis,bcjs->bcij", Cc, Bc)       # (B,nc,Q,Q)
    y_intra = jnp.einsum("bcij,bcijh,bcjhd->bcihd",
                         scores, L, xc)                  # h=nh, d=hd

    # chunk-final states: S_c = sum_j exp(cum_Q - cum_j) * B_j (x) xh_j
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)         # (B,nc,Q,nh)
    S = jnp.einsum("bcjh,bcjs,bcjhd->bchds", decay_out, Bc, xc)  # (B,nc,nh,hd,ns)

    # inter-chunk recurrence over c
    a_tot = jnp.exp(cum[:, :, -1, :])                    # (B,nc,nh)
    if h0 is None:
        h0 = jnp.zeros((B, nh, hd, ns), jnp.float32)

    def step(h, inp):
        at, Sc = inp                                     # (B,nh), (B,nh,hd,ns)
        h = h * at[:, :, None, None] + Sc
        return h, h

    hs_in = (a_tot.transpose(1, 0, 2), S.transpose(1, 0, 2, 3, 4))
    h_last, h_all = jax.lax.scan(step, h0, hs_in)        # h_all: (nc,B,nh,hd,ns)
    h_prev = jnp.concatenate([h0[None], h_all[:-1]], axis=0)  # state entering c
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)             # (B,nc,nh,hd,ns)

    # y_inter_i = exp(cum_i) * C_i . h_prev
    y_inter = jnp.einsum("bcih,bcis,bchds->bcihd",
                         jnp.exp(cum), Cc, h_prev)
    y = (y_intra + y_inter).reshape(B, Tp, nh, hd)
    return y[:, :T], h_last


def apply_mamba(p, x, cfg: ArchConfig, *, cache=None, chunk: int = 256,
                collect: bool = False):
    """Mamba-2 block. cache = dict(conv=(B,K-1,C), ssm=(B,nh,hd,ns)) for
    decode (T==1); ``collect`` returns the prefill-final cache.
    Returns (out, new_cache)."""
    B, T, d = x.shape
    xs, Bv, Cv, z, dt, d_in, ns, nh = _split_proj(p, x, cfg)
    hd = cfg.ssm_head_dim
    xBC = jnp.concatenate([xs, Bv, Cv], axis=-1)
    conv_prev = cache["conv"] if cache is not None else None
    xBC, conv_new = _causal_conv(xBC, p["conv_w"].astype(x.dtype),
                                 p["conv_b"].astype(x.dtype), conv_prev)
    xs, Bv, Cv = jnp.split(xBC, [d_in, d_in + ns], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,T,nh)
    a = -jnp.exp(p["a_log"])[None, None]                          # (B,T,nh) <0
    a_log = a * dt
    xh = xs.astype(jnp.float32).reshape(B, T, nh, hd) * dt[..., None]

    if cache is None:
        y, h_last = ssd_chunked(xh, a_log, Bv.astype(jnp.float32),
                                Cv.astype(jnp.float32), chunk=chunk)
        new_cache = {"conv": conv_new, "ssm": h_last} if collect else None
    else:
        h0 = cache["ssm"]
        hb = jnp.einsum("bthd,bts->bhds", xh, Bv.astype(jnp.float32))
        h_last = h0 * jnp.exp(a_log)[:, 0, :, None, None] + hb
        y = jnp.einsum("bts,bhds->bthd", Cv.astype(jnp.float32), h_last)
        new_cache = {"conv": conv_new, "ssm": h_last}

    y = y + p["d_skip"][None, None, :, None] * xs.astype(jnp.float32).reshape(B, T, nh, hd)
    y = y.reshape(B, T, d_in).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["w_out"].astype(x.dtype), new_cache


def mamba_scan_ref(xh, a_log, Bv, Cv, h0=None):
    """Naive sequential oracle for ssd_chunked (tests only)."""
    B, T, nh, hd = xh.shape
    ns = Bv.shape[-1]
    h = h0 if h0 is not None else jnp.zeros((B, nh, hd, ns), jnp.float32)

    def step(h, t_in):
        xt, at, bt, ct = t_in
        h = h * jnp.exp(at)[:, :, None, None] + jnp.einsum("bhd,bs->bhds", xt, bt)
        y = jnp.einsum("bs,bhds->bhd", ct, h)
        return h, y

    xs = (xh.transpose(1, 0, 2, 3), a_log.transpose(1, 0, 2),
          Bv.transpose(1, 0, 2), Cv.transpose(1, 0, 2))
    h_last, ys = jax.lax.scan(step, h, xs)
    return ys.transpose(1, 0, 2, 3), h_last
