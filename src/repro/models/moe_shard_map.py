"""shard_map MoE: explicit EP all-to-all dispatch (the optimized path).

Hypothesis (EXPERIMENTS.md SPerf-C): under pjit, the combine gather's
*backward* is a scatter-add of model-sharded cotangents into a data-sharded
buffer, which GSPMD lowers to a full-activation f32 all-reduce per MoE layer
(~193 GB/step on llama4-scout train_4k). Writing the dispatch as an explicit
``jax.lax.all_to_all`` inside ``shard_map`` bounds the traffic to the
capacity buffers by construction -- and ``all_to_all``'s transpose is
``all_to_all``, so the backward moves the same bounded bytes.

Routing reuses the prefix-stable stage from ``repro.models.moe``
(:func:`~repro.models.moe.route_tokens`) on the *local* (B_loc, S_loc)
block, so the slot/drop law is the same per-(row, expert) prefix-cumsum law
as the pjit path.  This impl is train-only: sequence shards route their
local chunk from local position 0 and routing state is not threaded across
calls (decode uses the pjit path, which carries occupancy counts).

Layout inside shard_map (mesh axes dp = ("pod","data") merged, tp = "model"):
  x block: (B_loc, S_loc, d)  [B over dp, S over tp (SP)]
  experts: E split over tp; d split over dp (FSDP -> all_gather on entry,
           psum_scatter on the gradient by AD of all_gather).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig
from repro.models.moe import (_combine_gather, _dispatch_gather,
                              dispatch_capacity, route_tokens)


def apply_moe_shard_map(p, x, cfg: ArchConfig, mesh, *, dp_axes, tp_axis):
    """x: (B, S, d) -> (B, S, d) with explicit EP all-to-all."""
    E = cfg.n_experts
    tp = mesh.shape[tp_axis]
    assert E % tp == 0, (E, tp)

    def body(x_blk, router, experts, shared):
        # x_blk: (B_loc, S_loc, d) -- local tokens, routed per local row
        Bl, Sl, d = x_blk.shape
        r = route_tokens(router, x_blk, cfg)
        cap = dispatch_capacity(Sl, cfg)
        flat = jnp.where(r.keep, r.expert_id * cap + r.within, E * cap)
        xe = _dispatch_gather(x_blk, flat, E, cap)       # (E, Bl, cap, d)
        xe = xe.reshape(E, Bl * cap, d)

        # EP all-to-all: split the expert dim over tp peers, concat capacity.
        # (E, Bl*cap, d) -> (E/tp, tp*Bl*cap, d): this shard now holds *its*
        # experts' tokens from every sequence-peer. all_to_all's transpose is
        # all_to_all -> bounded backward traffic by construction.
        xe = jax.lax.all_to_all(xe, tp_axis, 0, 1, tiled=True)

        # FSDP gather of this shard's expert weights over dp (bf16 operands;
        # AD turns this into psum_scatter on the weight gradient = ZeRO-3)
        cd = x_blk.dtype
        gather_axis = {"w_gate": 1, "w_up": 1, "w_down": 2}
        w = {k: jax.lax.all_gather(v.astype(cd), dp_axes,
                                   axis=gather_axis[k], tiled=True)
             for k, v in experts.items()}
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w["w_gate"])) \
            * jnp.einsum("ecd,edf->ecf", xe, w["w_up"]) \
            if cfg.mlp_type == "swiglu" else \
            jnp.square(jax.nn.relu(jnp.einsum("ecd,edf->ecf", xe, w["w_up"])))
        ye = jnp.einsum("ecf,efd->ecd", h, w["w_down"])

        # inverse all-to-all back to the dispatch layout
        ye = jax.lax.all_to_all(ye, tp_axis, 1, 0, tiled=True)
        yt = ye.reshape(E, Bl, cap, d).transpose(1, 0, 2, 3).reshape(
            Bl, E * cap, d)
        out = _combine_gather(yt, flat, r.gate, r.keep, E, cap)
        if cfg.moe_shared_expert:
            sh = {k: jax.lax.all_gather(v.astype(cd), dp_axes, axis=0,
                                        tiled=True)
                  for k, v in shared.items()}
            # shared expert weights are (d, ff)/(ff, d) FSDP-sharded on dim 0
            xt = x_blk.reshape(Bl * Sl, d)
            hh = jax.nn.silu(xt @ sh["w_gate"]) * (xt @ sh["w_up"]) \
                if cfg.mlp_type == "swiglu" else \
                jnp.square(jax.nn.relu(xt @ sh["w_up"]))
            out = out + (hh @ sh["w_down"]).reshape(Bl, Sl, d)
        return out

    dp = dp_axes
    shared = p.get("shared", {k: jnp.zeros((), x.dtype) for k in ()}) or {}
    in_specs = (
        P(dp, tp_axis, None),                        # x: B over dp, S over tp
        P(None, None),                               # router replicated
        {k: P(tp_axis, dp, None) if k in ("w_gate", "w_up")
         else P(tp_axis, None, dp) for k in p["experts"]},
        {k: P(dp, None) if k in ("w_gate", "w_up") else P(dp, None)
         for k in shared},
    )
    from repro.parallel.sharding import compat_shard_map
    fn = compat_shard_map(body, mesh=mesh, in_specs=in_specs,
                          out_specs=P(dp, tp_axis, None))
    return fn(x, p["router"], p["experts"], shared)
