"""shard_map MoE: explicit EP all-to-all dispatch (the optimized path).

Hypothesis (EXPERIMENTS.md SPerf-C): under pjit, the combine gather's
*backward* is a scatter-add of model-sharded cotangents into a data-sharded
buffer, which GSPMD lowers to a full-activation f32 all-reduce per MoE layer
(~193 GB/step on llama4-scout train_4k). Writing the dispatch as an explicit
``jax.lax.all_to_all`` inside ``shard_map`` bounds the traffic to the
capacity buffers by construction -- and ``all_to_all``'s transpose is
``all_to_all``, so the backward moves the same bounded bytes.

Layout inside shard_map (mesh axes dp = ("pod","data") merged, tp = "model"):
  x block: (B_loc, S_loc, d)  [B over dp, S over tp (SP)]
  experts: E split over tp; d split over dp (FSDP -> all_gather on entry,
           psum_scatter on the gradient by AD of all_gather).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig
from repro.models.layers import apply_mlp


def _local_dispatch(xt, router, E: int, cap: int, cf: float):
    """Route local tokens into (E, cap, d) buckets; returns (xe, combine)."""
    T, d = xt.shape
    logits = xt.astype(jnp.float32) @ router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_id = jax.lax.top_k(probs, 1)
    gate, expert_id = gate[:, 0], expert_id[:, 0]
    onehot = jax.nn.one_hot(expert_id, E, dtype=jnp.int32)
    slot = ((jnp.cumsum(onehot, axis=0) - 1) * onehot).sum(-1)
    keep = slot < cap
    flat = jnp.where(keep, expert_id * cap + slot, E * cap)
    inv = jnp.full((E * cap + 1,), T, jnp.int32).at[flat].set(
        jnp.arange(T, dtype=jnp.int32), mode="drop")[: E * cap]
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    xe = jnp.take(xt_pad, inv, axis=0).reshape(E, cap, d)
    return xe, (flat, gate, keep)


def _local_combine(ye, flat, gate, keep, E: int, cap: int):
    ye_flat = ye.reshape(E * cap, -1)
    ye_pad = jnp.concatenate(
        [ye_flat, jnp.zeros((1, ye_flat.shape[1]), ye_flat.dtype)], axis=0)
    back = jnp.take(ye_pad, jnp.minimum(flat, E * cap), axis=0)
    return back * (gate * keep).astype(back.dtype)[:, None]


def apply_moe_shard_map(p, x, cfg: ArchConfig, mesh, *, dp_axes, tp_axis):
    """x: (B, S, d) -> (B, S, d) with explicit EP all-to-all."""
    E = cfg.n_experts
    tp = mesh.shape[tp_axis]
    assert E % tp == 0, (E, tp)

    def body(x_blk, router, experts, shared):
        # x_blk: (B_loc, S_loc, d) -- local tokens
        Bl, Sl, d = x_blk.shape
        T = Bl * Sl
        xt = x_blk.reshape(T, d)
        cap = max(1, int(T / E * cfg.capacity_factor))
        xe, combine_state = _local_dispatch(xt, router, E, cap, cfg.capacity_factor)

        # EP all-to-all: split the expert dim over tp peers, concat capacity.
        # (E, cap, d) -> (E/tp, tp*cap, d): this shard now holds *its* experts'
        # tokens from every sequence-peer. all_to_all's transpose is
        # all_to_all -> bounded backward traffic by construction.
        xe = jax.lax.all_to_all(xe, tp_axis, 0, 1, tiled=True)

        # FSDP gather of this shard's expert weights over dp (bf16 operands;
        # AD turns this into psum_scatter on the weight gradient = ZeRO-3)
        cd = x_blk.dtype
        gather_axis = {"w_gate": 1, "w_up": 1, "w_down": 2}
        w = {k: jax.lax.all_gather(v.astype(cd), dp_axes,
                                   axis=gather_axis[k], tiled=True)
             for k, v in experts.items()}
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w["w_gate"])) \
            * jnp.einsum("ecd,edf->ecf", xe, w["w_up"]) \
            if cfg.mlp_type == "swiglu" else \
            jnp.square(jax.nn.relu(jnp.einsum("ecd,edf->ecf", xe, w["w_up"])))
        ye = jnp.einsum("ecf,efd->ecd", h, w["w_down"])

        # inverse all-to-all back to the dispatch layout
        ye = jax.lax.all_to_all(ye, tp_axis, 1, 0, tiled=True)
        out = _local_combine(ye, *combine_state, E, cap).reshape(Bl, Sl, d)
        if cfg.moe_shared_expert:
            sh = {k: jax.lax.all_gather(v.astype(cd), dp_axes, axis=0,
                                        tiled=True)
                  for k, v in shared.items()}
            # shared expert weights are (d, ff)/(ff, d) FSDP-sharded on dim 0
            hh = jax.nn.silu(xt @ sh["w_gate"]) * (xt @ sh["w_up"]) \
                if cfg.mlp_type == "swiglu" else \
                jnp.square(jax.nn.relu(xt @ sh["w_up"]))
            out = out + (hh @ sh["w_down"]).reshape(Bl, Sl, d)
        return out

    dp = dp_axes
    shared = p.get("shared", {k: jnp.zeros((), x.dtype) for k in ()}) or {}
    in_specs = (
        P(dp, tp_axis, None),                        # x: B over dp, S over tp
        P(None, None),                               # router replicated
        {k: P(tp_axis, dp, None) if k in ("w_gate", "w_up")
         else P(tp_axis, None, dp) for k in p["experts"]},
        {k: P(dp, None) if k in ("w_gate", "w_up") else P(dp, None)
         for k in shared},
    )
    from repro.parallel.sharding import compat_shard_map
    fn = compat_shard_map(body, mesh=mesh, in_specs=in_specs,
                          out_specs=P(dp, tp_axis, None))
    return fn(x, p["router"], p["experts"], shared)
