"""Model assembly: scanned superblock stacks, train forward, KV-cache decode.

The stack is ``block_unit * n_repeats`` (+ optional prologue layers). Per-slot
params are stacked along the repeat axis and the repeat loop is a
``jax.lax.scan`` with per-step remat -- one superblock of HLO regardless of
depth, which keeps 96-layer/340B dry-run compiles tractable and bounds
activation memory.

Caches: per-slot stacked pytrees; decode scans (params, cache) pairs and
emits updated cache slices. Attention caches for ``attn_local`` layers are
ring buffers bounded by the window (what makes gemma-3 long_500k decodable).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.masks import NEG_INF, AttnMaskSpec
from repro.core.precision import policy as precision_policy
from repro.models.config import ArchConfig
from repro.models import layers as L
from repro.models import mamba2, moe, rwkv6

Params = Dict[str, Any]

ATTN_KINDS = ("attn", "attn_local", "attn_global", "attn+moe", "shared_attn")


# ---------------------------------------------------------------- init ------

def init_block(key, kind: str, cfg: ArchConfig) -> Params:
    if kind in ("attn", "attn_local", "attn_global", "attn+moe", "shared_attn"):
        k1, k2 = jax.random.split(key)
        p = {"ln1": L.init_rmsnorm(cfg.d_model),
             "attn": L.init_attention(k1, cfg),
             "ln2": L.init_rmsnorm(cfg.d_model)}
        if kind == "attn+moe":
            p["ffn"] = moe.init_moe(k2, cfg)
        else:
            p["ffn"] = L.init_mlp(k2, cfg)
        return p
    if kind == "mamba":
        return {"ln": L.init_rmsnorm(cfg.d_model),
                "mixer": mamba2.init_mamba(key, cfg)}
    if kind == "rwkv":
        return {"ln1": L.init_rmsnorm(cfg.d_model),
                "ln2": L.init_rmsnorm(cfg.d_model),
                "mixer": rwkv6.init_rwkv(key, cfg)}
    raise ValueError(kind)


def init_params(key, cfg: ArchConfig) -> Params:
    keys = jax.random.split(key, 8)
    d, V = cfg.d_model, cfg.padded_vocab
    p: Params = {
        "embed": jax.random.normal(keys[0], (V, d), jnp.float32) * (d ** -0.5),
        "final_norm": L.init_rmsnorm(d),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = jax.random.normal(keys[1], (d, V), jnp.float32) * (d ** -0.5)

    # stacked superblock params: one vmapped init per slot
    slot_params = []
    for slot, kind in enumerate(cfg.block_unit):
        slot_keys = jax.random.split(jax.random.fold_in(keys[2], slot), cfg.n_repeats)
        slot_params.append(jax.vmap(lambda k: init_block(k, kind, cfg))(slot_keys))
    p["blocks"] = tuple(slot_params)

    if cfg.shared_attn_every:
        p["shared_attn"] = init_block(keys[3], "shared_attn", cfg)
    if getattr(cfg, "n_prologue", 0):
        pro_keys = jax.random.split(keys[4], cfg.n_prologue)
        p["prologue"] = jax.vmap(
            lambda k: init_block(k, cfg.block_unit[0], cfg))(pro_keys)
    return p


# --------------------------------------------------------------- blocks -----

def _window_for(kind: str, cfg: ArchConfig) -> Optional[int]:
    return cfg.local_window if kind == "attn_local" else None


def apply_block(kind: str, p: Params, x, cfg: ArchConfig, *, impl="chunked",
                cache=None, pos=None, collect_kv: int = 0, moe_fn=None,
                kv_quant: Optional[str] = None, attn_mask=None):
    """One sub-layer. Returns (x, new_cache). ``collect_kv`` > 0 makes the
    prefill path emit a decode cache of that capacity.  ``moe_fn`` overrides
    ``moe.apply_moe`` for attn+moe blocks (same signature/returns) -- the
    two-phase serving loop injects its route-then-execute stage here.
    ``kv_quant`` (prefill only) collects full-context attention caches as
    per-position narrow values + f32 scales (see ``layers.apply_attention``);
    decode detects a quantized cache by its scale leaves, no flag needed.
    ``attn_mask`` (an ``AttnMaskSpec``, prefill only) routes attention
    through the block-sparse stream walk."""
    if kind in ATTN_KINDS:
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        attn_cache = cache.get("attn") if cache else None
        a, new_attn = L.apply_attention(
            p["attn"], h, cfg, window=_window_for(kind, cfg), impl=impl,
            cache=attn_cache, cache_len=pos, collect_kv=collect_kv,
            kv_quant=kv_quant, attn_mask=attn_mask)
        x = x + a
        h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        moe_counts = None
        if kind == "attn+moe":
            # thread the routing occupancy (prefix-stable slots): decode
            # passes the cached per-(row, expert) counts + absolute position
            f, moe_counts = (moe_fn or moe.apply_moe)(
                p["ffn"], h, cfg, counts=cache.get("moe") if cache else None,
                pos=pos)
        else:
            f = L.apply_mlp(p["ffn"], h, cfg)
        x = x + f
        if new_attn is None:
            return x, None
        new_cache = {"attn": new_attn}
        if kind == "attn+moe":
            new_cache["moe"] = moe_counts
        return x, new_cache
    if kind == "mamba":
        h = L.rmsnorm(p["ln"], x, cfg.norm_eps)
        m, new_c = mamba2.apply_mamba(p["mixer"], h, cfg, cache=cache,
                                      collect=bool(collect_kv))
        return x + m, new_c
    if kind == "rwkv":
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        t_cache = ({"shift_t": cache["shift_t"], "wkv": cache["wkv"]}
                   if cache else None)
        t, new_t = rwkv6.apply_rwkv_time(p["mixer"], h, cfg, cache=t_cache,
                                         collect=bool(collect_kv))
        x = x + t
        h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        c_cache = {"shift_c": cache["shift_c"]} if cache else None
        c, new_c = rwkv6.apply_rwkv_channel(p["mixer"], h, cfg, cache=c_cache,
                                            collect=bool(collect_kv))
        x = x + c
        new = None if new_t is None else {**new_t, **(new_c or {})}
        return x, new
    raise ValueError(kind)


def _superblock(params_slots, x, cfg: ArchConfig, *, impl, shared_p,
                step_idx, caches_slots=None, pos=None):
    """Apply one superblock (all slots) + optional shared attention."""
    from repro.parallel import context as pctx
    from repro.parallel.sharding import constrain
    new_caches = []
    for slot, kind in enumerate(cfg.block_unit):
        c = caches_slots[slot] if caches_slots is not None else None
        x, nc = apply_block(kind, params_slots[slot], x, cfg, impl=impl,
                            cache=c, pos=pos)
        if pctx.ACT_SPEC is not None:
            # re-anchor the residual layout after every block: keeps the TP
            # row-parallel reduction a reduce-scatter (not a full all-reduce)
            x = constrain(x, pctx.ACT_SPEC)
        new_caches.append(nc)
    if cfg.shared_attn_every:
        fire = (step_idx % cfg.shared_attn_every) == (cfg.shared_attn_every - 1)
        x = jax.lax.cond(
            fire,
            lambda x: apply_block("shared_attn", shared_p, x, cfg, impl=impl)[0],
            lambda x: x,
            x)
    return x, (tuple(new_caches) if caches_slots is not None else None)


# -------------------------------------------------------------- forward -----

def hidden_forward(params: Params, tokens: jax.Array, cfg: ArchConfig, *,
                   embeddings: Optional[jax.Array] = None,
                   impl: str = "chunked", remat: bool = True) -> jax.Array:
    """Backbone forward: embeddings -> scanned superblocks -> final norm.
    Returns the normed hidden states (B, S_total, d) in compute dtype."""
    from repro.parallel import context as pctx
    from repro.parallel.sharding import constrain
    pol = precision_policy(cfg.policy)
    cd = pol.compute_dtype
    x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    if embeddings is not None:
        x = jnp.concatenate([embeddings.astype(cd), x], axis=1)
    if pctx.ACT_SPEC is not None:
        x = constrain(x, pctx.ACT_SPEC)

    if "prologue" in params:
        def pro_body(x, p_slice):
            y, _ = apply_block(cfg.block_unit[0], p_slice, x, cfg, impl=impl)
            return y, None
        x, _ = jax.lax.scan(pro_body, x, params["prologue"])

    shared_p = params.get("shared_attn")

    def body(x, inp):
        p_slots, step_idx = inp
        y, _ = _superblock(p_slots, x, cfg, impl=impl, shared_p=shared_p,
                           step_idx=step_idx)
        if pctx.ACT_SPEC is not None:
            y = constrain(y, pctx.ACT_SPEC)
        return y, None

    if remat:
        body = jax.checkpoint(body)
    steps = jnp.arange(cfg.n_repeats)
    x, _ = jax.lax.scan(body, x, (params["blocks"], steps))
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps)


def forward(params: Params, tokens: jax.Array, cfg: ArchConfig, *,
            embeddings: Optional[jax.Array] = None, impl: str = "chunked",
            remat: bool = True) -> jax.Array:
    """Train/prefill forward. tokens: (B, S_text) int32; optional frontend
    ``embeddings``: (B, S_front, d) prepended (vlm/audio stubs). Returns
    logits (B, S_total, V) in f32."""
    x = hidden_forward(params, tokens, cfg, embeddings=embeddings, impl=impl,
                       remat=remat)
    cd = x.dtype
    unemb = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    return (x @ unemb.astype(cd)).astype(jnp.float32)


def loss_fn(params: Params, tokens: jax.Array, cfg: ArchConfig, *,
            embeddings: Optional[jax.Array] = None, impl: str = "chunked",
            seq_chunk: Optional[int] = None):
    """Next-token cross-entropy over the token region.

    ``seq_chunk``: compute logits + CE in sequence chunks under remat so the
    (B, S, V) logits tensor is never materialized (essential for 256k-vocab
    archs at 1M tokens/step)."""
    from repro.parallel import context as pctx
    from repro.parallel.sharding import constrain
    h = hidden_forward(params, tokens, cfg, embeddings=embeddings, impl=impl)
    if embeddings is not None:
        h = h[:, embeddings.shape[1]:]
    h = h[:, :-1]
    tgt = tokens[:, 1:]
    cd = h.dtype
    unemb = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    unemb = unemb.astype(cd)

    def ce(h_blk, tgt_blk):
        logits = h_blk @ unemb
        if pctx.LOGIT_SPEC is not None:
            logits = constrain(logits, pctx.LOGIT_SPEC)
        logits = logits.astype(jnp.float32)
        if cfg.padded_vocab != cfg.vocab_size:  # mask pad ids out of the CE
            pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
            logits = jnp.where(pad_mask, NEG_INF, logits)
        lp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.take_along_axis(lp, tgt_blk[..., None], axis=-1)[..., 0]

    Sm1 = h.shape[1]
    if seq_chunk is None or seq_chunk >= Sm1:
        return ce(h, tgt).mean()
    n = Sm1 // seq_chunk
    main, tail = h[:, : n * seq_chunk], h[:, n * seq_chunk:]
    tgt_main, tgt_tail = tgt[:, : n * seq_chunk], tgt[:, n * seq_chunk:]
    hc = main.reshape(h.shape[0], n, seq_chunk, -1).transpose(1, 0, 2, 3)
    tc = tgt_main.reshape(tgt.shape[0], n, seq_chunk).transpose(1, 0, 2)

    def body(acc, inp):
        hb, tb = inp
        return acc + ce(hb, tb).sum(), None

    body = jax.checkpoint(body)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, tc))
    if tail.shape[1]:
        total = total + ce(tail, tgt_tail).sum()
    return total / (h.shape[0] * Sm1)


def _cache_to_dtype(cache, cd, cache_dtype):
    """Convert compute-dtype cache leaves to the decode cache dtype,
    leaving quantization scale leaves (``k_scale``/``v_scale``) untouched --
    they are f32 by contract even when the compute dtype is f32."""
    skip = ("k_scale", "v_scale")

    def conv(path, a):
        if path and getattr(path[-1], "key", None) in skip:
            return a
        return a.astype(cache_dtype) if a.dtype == cd else a

    return jax.tree_util.tree_map_with_path(conv, cache)


def prefill(params: Params, tokens: jax.Array, cfg: ArchConfig, *,
            max_seq: int, embeddings: Optional[jax.Array] = None,
            impl: str = "chunked", cache_dtype=jnp.bfloat16,
            kv_quant: Optional[str] = None, attn_mask=None):
    """Serving prefill: forward over the prompt, emitting (last_logits,
    decode cache filled to ``tokens`` length, next position).  ``kv_quant``
    stores full-context KV caches as per-position narrow values + f32
    scales (local ring buffers stay wide).  ``attn_mask`` (AttnMaskSpec)
    routes attention through the block-sparse stream walk."""
    pol = precision_policy(cfg.policy)
    cd = pol.compute_dtype
    x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    if embeddings is not None:
        x = jnp.concatenate([embeddings.astype(cd), x], axis=1)
    S_total = x.shape[1]
    shared_p = params.get("shared_attn")
    cache: Dict[str, Any] = {}

    if "prologue" in params:
        def pro_body(x, p_slice):
            y, c = apply_block(cfg.block_unit[0], p_slice, x, cfg, impl=impl,
                               collect_kv=max_seq, kv_quant=kv_quant,
                               attn_mask=attn_mask)
            return y, c
        x, pro_cache = jax.lax.scan(pro_body, x, params["prologue"])
        cache["prologue"] = pro_cache

    def body(x, inp):
        p_slots, step_idx = inp
        slot_caches = []
        y = x
        for slot, kind in enumerate(cfg.block_unit):
            y, c = apply_block(kind, p_slots[slot], y, cfg, impl=impl,
                               collect_kv=max_seq, kv_quant=kv_quant,
                               attn_mask=attn_mask)
            slot_caches.append(c)
        if cfg.shared_attn_every:
            fire = (step_idx % cfg.shared_attn_every) == (cfg.shared_attn_every - 1)
            y2, c2 = apply_block("shared_attn", shared_p, y, cfg, impl=impl,
                                 collect_kv=max_seq, kv_quant=kv_quant,
                                 attn_mask=attn_mask)
            y = jnp.where(fire, y2, y)
            slot_caches.append(c2)
        return y, tuple(slot_caches)

    steps = jnp.arange(cfg.n_repeats)
    x, slot_caches = jax.lax.scan(body, x, (params["blocks"], steps))
    cache["slots"] = slot_caches

    x_last = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    unemb = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = (x_last @ unemb.astype(cd)).astype(jnp.float32)
    # KV caches collected in compute dtype; convert to the decode cache dtype
    cache = _cache_to_dtype(cache, cd, cache_dtype)
    return logits, cache, jnp.asarray(S_total, jnp.int32)


# --------------------------------------------------------------- decode -----

def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16, kv_quant: Optional[str] = None) -> Params:
    """Stacked decode caches, one entry per slot (+ shared-attn slot).

    Every leaf carries the batch at dim 1 ((n_repeats, B, ...)), and all
    per-request decode state -- attention KV, MoE routing occupancy
    ``counts[b, e]``, SSM/RWKV recurrent state -- is indexed by batch row.
    Batch rows are therefore independent *request slots*: a continuous-
    batching scheduler (``launch.serve.ServeScheduler``) evicts a finished
    sequence and admits a new one by scattering a fresh single-request
    prefill cache into that row, with zero effect on its neighbours."""
    d = cfg.d_model
    hd, Hkv = cfg.hd, cfg.n_kv_heads

    def attn_cache(window):
        Lc = min(max_seq, window) if window else max_seq
        shp = (cfg.n_repeats, batch, Hkv, Lc, hd)
        if kv_quant is not None and not window:
            # Quantized full-context cache: narrow values + per-position
            # f32 scales (scale 1.0 = the all-zero convention of
            # precision.quantize_rows).  Local ring buffers stay wide.
            from repro.core import precision
            qdt = precision.QUANT_DTYPES[kv_quant]
            return {"attn": {
                "k": jnp.zeros(shp, qdt),
                "k_scale": jnp.ones(shp[:-1], jnp.float32),
                "v": jnp.zeros(shp, qdt),
                "v_scale": jnp.ones(shp[:-1], jnp.float32)}}
        return {"attn": {
            "k": jnp.zeros(shp, dtype),
            "v": jnp.zeros(shp, dtype)}}

    def mamba_cache():
        d_in = cfg.ssm_expand * d
        nh = d_in // cfg.ssm_head_dim
        conv_ch = d_in + 2 * cfg.ssm_state
        return {"conv": jnp.zeros((cfg.n_repeats, batch, cfg.ssm_conv - 1, conv_ch), dtype),
                "ssm": jnp.zeros((cfg.n_repeats, batch, nh, cfg.ssm_head_dim,
                                  cfg.ssm_state), jnp.float32)}

    def rwkv_cache():
        nh = d // rwkv6.HEAD_DIM
        return {"wkv": jnp.zeros((cfg.n_repeats, batch, nh, rwkv6.HEAD_DIM,
                                  rwkv6.HEAD_DIM), jnp.float32),
                "shift_t": jnp.zeros((cfg.n_repeats, batch, 1, d), dtype),
                "shift_c": jnp.zeros((cfg.n_repeats, batch, 1, d), dtype)}

    def slot_cache(kind, n):
        if kind == "attn+moe":
            # MoE routing occupancy: per-(row, expert) counts make decode
            # slot assignment prefix-stable (see models.moe)
            c = attn_cache(None)
            c["moe"] = jnp.zeros((cfg.n_repeats, batch, cfg.n_experts),
                                 jnp.int32)
        elif kind in ("attn", "attn_global", "shared_attn"):
            c = attn_cache(None)
        elif kind == "attn_local":
            c = attn_cache(cfg.local_window)
        elif kind == "mamba":
            c = mamba_cache()
        elif kind == "rwkv":
            c = rwkv_cache()
        else:
            raise ValueError(kind)
        if n != cfg.n_repeats:  # re-stack with a different leading dim
            c = jax.tree.map(lambda a: jnp.zeros((n,) + a.shape[1:], a.dtype), c)
        return c

    slots = [slot_cache(kind, cfg.n_repeats) for kind in cfg.block_unit]
    if cfg.shared_attn_every:
        slots.append(slot_cache("shared_attn", cfg.n_repeats))
    out = {"slots": tuple(slots)}
    if cfg.n_prologue:
        out["prologue"] = slot_cache(cfg.block_unit[0], cfg.n_prologue)
    return out


def _decode_block_attn(kind, p, x, cfg, cache, pos, dtype, moe_fn=None):
    """Attention decode with ring-buffer handling for local layers.

    ``pos`` is an int32 scalar (whole-batch decode) or a ``(B,)`` vector of
    per-row positions (continuous batching); both paths write the same
    cache slots and mask the same tail per row."""
    window = _window_for(kind, cfg)
    kc = cache["attn"]["k"]
    Lc = kc.shape[2]
    if window and Lc == window:
        # ring buffer: write slot = pos % window; all filled slots visible
        pos_a = jnp.asarray(pos)
        slot = pos_a % window
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        if pos_a.ndim:  # per-row ring slots (continuous batching)
            slot = slot.reshape(-1).astype(jnp.int32)
            q, k1, v1 = L._qkv(p["attn"], h, cfg,
                               pos_a.reshape(-1)[:, None, None])
            b_idx = jnp.arange(x.shape[0])
            knew = kc.at[b_idx, :, slot].set(k1[:, :, 0].astype(kc.dtype))
            vnew = cache["attn"]["v"].at[b_idx, :, slot].set(
                v1[:, :, 0].astype(kc.dtype))
        else:
            q, k1, v1 = L._qkv(p["attn"], h, cfg, jnp.full((1,), pos_a))
            knew = jax.lax.dynamic_update_slice_in_dim(
                kc, k1.astype(kc.dtype), slot, axis=2)
            vnew = jax.lax.dynamic_update_slice_in_dim(
                cache["attn"]["v"], v1.astype(kc.dtype), slot, axis=2)
        from repro.kernels.flash_attention.ops import decode_attention
        a = decode_attention(q, knew, vnew,
                             kv_len=jnp.minimum(pos_a + 1, window))
        a = a.transpose(0, 2, 1, 3).reshape(x.shape[0], 1, cfg.n_heads * cfg.hd)
        x = x + a @ p["attn"]["wo"].astype(a.dtype)
        h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        # ring buffers exist only for attn_local layers, which are never MoE
        f = L.apply_mlp(p["ffn"], h, cfg)
        return x + f, {"attn": {"k": knew, "v": vnew}}
    return apply_block(kind, p, x, cfg, cache=cache, pos=pos, moe_fn=moe_fn)


def blank_cache_row(cache, row: int):
    """Reset one batch row of a stacked decode cache to its freshly
    initialised state: zeros everywhere except quantization scale leaves
    (``k_scale``/``v_scale``), which reset to 1.0 -- the all-zero
    convention of ``precision.quantize_rows``, matching ``init_cache``.

    The eviction half of the slot contract in :func:`init_cache`: a
    scheduler that fails a poisoned request scatter-blanks its row so
    stale NaN/Inf state cannot leak into a later prefill-refill, with zero
    effect on neighbouring rows."""

    def blank(path, a):
        if a.ndim < 2:
            return a
        fill = (jnp.ones if path and getattr(path[-1], "key", None)
                in ("k_scale", "v_scale") else jnp.zeros)
        return a.at[:, row].set(fill(a.shape[2:], a.dtype))

    return jax.tree_util.tree_map_with_path(blank, cache)


def cache_capacity(cache, *, ring_window: Optional[int] = None) -> Optional[int]:
    """Static sequence capacity of a decode cache: the minimum cache length
    over its full (non-ring) attention slots, or None for cache-free /
    attention-free stacks.  Ring buffers (``attn_local``) wrap by
    construction and never overflow, so when ``ring_window`` is given
    (``cfg.local_window``) leaves of exactly that length are excluded --
    decode identifies rings the same way (``Lc == window`` in
    ``_decode_block_attn``).  This is what callers must host-check ``pos``
    against before a decode write: the cache update is a
    ``dynamic_update_slice`` / scatter, and XLA *clamps / drops*
    out-of-bounds writes instead of failing, which silently corrupts the
    last cache slot (see ``ServeLoop.decode_step``)."""
    caps = []

    def visit(node):
        if isinstance(node, dict):
            if "attn" in node and isinstance(node["attn"], dict) \
                    and "k" in node["attn"]:
                caps.append(node["attn"]["k"].shape[3])
            else:
                for v in node.values():
                    visit(v)
        elif isinstance(node, (tuple, list)):
            for v in node:
                visit(v)

    visit(cache)
    if ring_window is not None:
        caps = [c for c in caps if c != ring_window]
    return min(caps) if caps else None


def check_cache_fits(cache, pos, *, who: str = "decode_step",
                     cfg: Optional[ArchConfig] = None):
    """Raise (host-side) when a concrete ``pos`` would write past the decode
    cache capacity.  ``pos`` may be a scalar or a per-row vector; traced
    positions are the caller's responsibility (the fused jit path cannot
    host-check -- ``ServeLoop`` checks before dispatching).  Pass ``cfg`` so
    local-layer ring buffers (capacity = ``cfg.local_window``, wrap forever)
    are not mistaken for the overflow bound."""
    if isinstance(pos, jax.core.Tracer):
        return
    ring = cfg.local_window if cfg is not None else None
    cap = cache_capacity(cache, ring_window=ring)
    if cap is None:
        return
    import numpy as _np
    top = int(_np.max(_np.asarray(pos)))
    if top >= cap:
        raise ValueError(
            f"{who}: KV-cache overflow -- write position {top} >= cache "
            f"capacity {cap} (max_seq). The cache update would be silently "
            "clamped by XLA, corrupting the last cache slot and generating "
            "garbage tokens; grow max_seq or stop the sequence.")


def decode_step(params: Params, cfg: ArchConfig, cache, pos, tokens_1,
                dtype=jnp.bfloat16) -> Tuple[jax.Array, Any]:
    """One-token decode. tokens_1: (B, 1) int32; pos: () int32 current fill,
    or a (B,) int32 vector of per-row fills (continuous batching -- every
    batch row decodes at its own position, bit-identical per row to the
    scalar path at that position).
    Returns (logits (B, 1, V) f32, new_cache)."""
    pol = precision_policy(cfg.policy)
    cd = pol.compute_dtype
    x = jnp.take(params["embed"], tokens_1, axis=0).astype(cd)
    shared_p = params.get("shared_attn")
    new_cache = dict(cache)

    if "prologue" in params:
        def pro_body(x, inp):
            p_slice, c_slice = inp
            y, nc = apply_block(cfg.block_unit[0], p_slice, x, cfg,
                                cache=c_slice, pos=pos)
            return y, nc
        x, pro_cache = jax.lax.scan(
            pro_body, x, (params["prologue"], cache["prologue"]))
        new_cache["prologue"] = pro_cache

    def body(x, inp):
        p_slots, c_slots, step_idx = inp
        new_caches = []
        y = x
        for slot, kind in enumerate(cfg.block_unit):
            c = c_slots[slot]
            if kind in ATTN_KINDS:
                y, nc = _decode_block_attn(kind, p_slots[slot], y, cfg, c, pos, dtype)
            else:
                y, nc = apply_block(kind, p_slots[slot], y, cfg, cache=c, pos=pos)
            new_caches.append(nc)
        if cfg.shared_attn_every:
            fire = (step_idx % cfg.shared_attn_every) == (cfg.shared_attn_every - 1)
            c = c_slots[-1]
            y2, nc = _decode_block_attn("shared_attn", shared_p, y, cfg, c, pos, dtype)
            y = jnp.where(fire, y2, y)
            nc = jax.tree.map(lambda new, old: jnp.where(fire, new, old), nc, c)
            new_caches.append(nc)
        return y, tuple(new_caches)

    steps = jnp.arange(cfg.n_repeats)
    x, slot_caches = jax.lax.scan(
        body, x, (params["blocks"], cache["slots"], steps))
    new_cache["slots"] = slot_caches
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    unemb = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    return (x @ unemb.astype(cd)).astype(jnp.float32), new_cache


# --- cached jitted per-layer steps -------------------------------------------
#
# The layered decode/prefill paths below interleave *host* work (two-phase
# MoE routing) between layers, which rules out one whole-model jit.  Running
# every layer op-by-op instead taxes each decode step with hundreds of eager
# dispatches (the PR-3 "host-dispatch tax").  Middle ground: one jitted
# program per (cfg, layer kind) -- lru-cached here, while jit's own cache
# keys the (x, cache, pos) *shapes* -- so a whole decode phase reuses a
# handful of compiled programs and the only eager seams left are the
# intentional host routing yields.

@functools.lru_cache(maxsize=None)
def _layer_decode_jit(cfg: ArchConfig, kind: str):
    """Whole-layer one-token decode step (any kind; attn+moe dispatches its
    MoE in-trace, i.e. without the two-phase host yield)."""
    def fn(p, x, cache, pos):
        if kind in ATTN_KINDS:
            return _decode_block_attn(kind, p, x, cfg, cache, pos, None)
        return apply_block(kind, p, x, cfg, cache=cache, pos=pos)
    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _layer_decode_attn_head_jit(cfg: ArchConfig):
    """The attention half of an attn+moe decode layer, up to the host MoE
    yield: ln1 + attention + residual + ln2.  Returns (x_mid, h, new_attn).
    attn+moe layers never use ring buffers (see _decode_block_attn)."""
    def fn(p, x, attn_cache, pos):
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        a, new_attn = L.apply_attention(
            p["attn"], h, cfg, window=None, impl="chunked", cache=attn_cache,
            cache_len=pos, collect_kv=0)
        x = x + a
        h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        return x, h, new_attn
    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _layer_decode_attn_route_jit(cfg: ArchConfig, capacity: int):
    """The attention half of an attn+moe decode layer FUSED with MoE route
    phase 1 (``moe.route_phase1``): ln1 + attention + residual + ln2 +
    router matmul + prefix-stable slot cumsums, one program.  The pipelined
    serving loop (``pipeline_depth=1``) uses this so each layer's routing
    arrays are dispatched *with* its attention -- one program ahead of the
    host route stage -- and the host then fetches only the small ``(B, S)``
    slot stream (``moe.plan_from_phase1``), never the hidden state.
    ``capacity`` is the static dispatch capacity the slot encoding assumes
    (always 1 for single-token decode, see ``moe.dispatch_capacity``)."""
    def fn(p, x, attn_cache, counts, pos):
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        a, new_attn = L.apply_attention(
            p["attn"], h, cfg, window=None, impl="chunked", cache=attn_cache,
            cache_len=pos, collect_kv=0)
        x = x + a
        h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        ph1 = moe.route_phase1(p["ffn"]["router"], h, cfg, counts, pos,
                               capacity)
        return x, h, new_attn, ph1
    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _layer_prefill_jit(cfg: ArchConfig, kind: str, collect_kv: int,
                       impl: str, kv_quant: Optional[str] = None,
                       attn_mask: Optional[AttnMaskSpec] = None):
    """Whole-layer prefill step (cache-collecting forward).  ``attn_mask``
    is a frozen (hashable) AttnMaskSpec so mask-routed prefills share this
    cache; the concrete BlockMask is built at trace time from the static
    sequence length."""
    def fn(p, x):
        return apply_block(kind, p, x, cfg, impl=impl, collect_kv=collect_kv,
                           kv_quant=kv_quant, attn_mask=attn_mask)
    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _layer_prefill_attn_head_jit(cfg: ArchConfig, kind: str, collect_kv: int,
                                 impl: str, kv_quant: Optional[str] = None,
                                 attn_mask: Optional[AttnMaskSpec] = None):
    """Prefill attention half of an attn+moe layer (up to the MoE yield)."""
    def fn(p, x):
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        a, new_attn = L.apply_attention(
            p["attn"], h, cfg, window=_window_for(kind, cfg), impl=impl,
            cache=None, cache_len=None, collect_kv=collect_kv,
            kv_quant=kv_quant, attn_mask=attn_mask)
        x = x + a
        h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        return x, h, new_attn
    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _layer_prefill_attn_route_jit(cfg: ArchConfig, kind: str,
                                  collect_kv: int, impl: str, capacity: int,
                                  kv_quant: Optional[str] = None,
                                  attn_mask: Optional[AttnMaskSpec] = None):
    """Prefill twin of :func:`_layer_decode_attn_route_jit`: attention half
    fused with MoE route phase 1 for a fresh sequence (zero occupancy,
    position 0); ``capacity`` is static per prompt length."""
    def fn(p, x):
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        a, new_attn = L.apply_attention(
            p["attn"], h, cfg, window=_window_for(kind, cfg), impl=impl,
            cache=None, cache_len=None, collect_kv=collect_kv,
            kv_quant=kv_quant, attn_mask=attn_mask)
        x = x + a
        h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        ph1 = moe.route_phase1(p["ffn"]["router"], h, cfg, None, 0, capacity)
        return x, h, new_attn, ph1
    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _final_logits_jit(cfg: ArchConfig, last_only: bool):
    """final rmsnorm + unembed matmul as one program (``last_only`` takes
    the trailing position first, the prefill contract)."""
    def fn(norm_p, emb_or_unemb, x):
        if last_only:
            x = x[:, -1:]
        x = L.rmsnorm(norm_p, x, cfg.norm_eps)
        unemb = emb_or_unemb.T if cfg.tie_embeddings else emb_or_unemb
        return (x @ unemb.astype(x.dtype)).astype(jnp.float32)
    return jax.jit(fn)


def _unemb_param(params: Params, cfg: ArchConfig):
    return params["embed"] if cfg.tie_embeddings else params["unembed"]


def _tree_take(tree, i):
    """Slice index ``i`` off every leaf's leading (repeat) dim."""
    return jax.tree.map(lambda a: a[i], tree)


def _tree_stack(per_step):
    """Re-stack per-repeat cache trees along a new leading dim."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_step)


def decode_step_layered(params: Params, cfg: ArchConfig, cache, pos,
                        tokens_1, dtype=jnp.bfloat16, *, moe_fn=None,
                        route_ahead: bool = False
                        ) -> Tuple[jax.Array, Any]:
    """One-token decode with the repeat loop unrolled at the Python level.

    Computes the same function as :func:`decode_step`, but layer by layer
    instead of one ``lax.scan`` -- which is what lets a serving loop
    interleave *host-side* work between layers: the two-phase MoE stage
    (``launch.serve.ServeLoop``) routes each attn+moe layer on host and runs
    only the expert/combine phase compiled, something a scan body can never
    yield back for.  Every layer runs as a cached jitted step
    (:func:`_layer_decode_jit` / :func:`_layer_decode_attn_head_jit`, keyed
    on (cfg, kind) here and on the x/cache shapes by jit itself), so the
    host-dispatch tax is one call per layer, not one per op.  ``moe_fn`` is
    threaded to every attn+moe block (signature of ``moe.apply_moe``);
    ``pos`` should be concrete here (a Python int, or an int ``(B,)``
    numpy vector for continuous batching -- per-row positions ride through
    attention writes, RoPE, and the prefix-stable MoE occupancy exactly like
    the scalar path does per row) so host routing sees real positions -- it
    rides into the jitted steps as a traced scalar/vector, so new positions
    do NOT retrace.  Being concrete, ``pos`` is also host-checked against
    the cache capacity here (:func:`check_cache_fits`) -- the layered guard
    against the silent out-of-bounds write clamp.  ``dtype`` is accepted for
    signature parity with :func:`decode_step` and (like there) unused: cache
    dtypes follow the cache arrays themselves.

    ``route_ahead=True`` (the pipelined serving path) fuses MoE route
    phase 1 into each attn+moe layer's jitted attention step
    (:func:`_layer_decode_attn_route_jit`) and hands the resulting
    ``moe.Phase1`` to ``moe_fn`` as the ``phase1`` keyword -- the routing
    arrays are dispatched one program ahead of the host route stage, so the
    host only ever fetches the small slot stream, never the hidden state.
    The computed values are identical to ``route_ahead=False``.
    """
    check_cache_fits(cache, pos, who="decode_step_layered", cfg=cfg)
    pol = precision_policy(cfg.policy)
    cd = pol.compute_dtype
    x = jnp.take(params["embed"], tokens_1, axis=0).astype(cd)
    shared_p = params.get("shared_attn")
    new_cache = dict(cache)
    pos_t = jnp.asarray(pos, jnp.int32)  # traced side; host moe keeps `pos`
    take, restack = _tree_take, _tree_stack
    if route_ahead:
        # same capacity route_moe would compute (C = 1 for S = 1 decode)
        route_cap = moe.dispatch_capacity(tokens_1.shape[1], cfg, pos0=pos)

    def layered_block(kind, p_i, x, c_i):
        if kind == "attn+moe" and moe_fn is not None:
            if route_ahead:
                x, h, new_attn, ph1 = _layer_decode_attn_route_jit(
                    cfg, route_cap)(p_i, x, c_i["attn"], c_i["moe"], pos_t)
                f, moe_counts = moe_fn(
                    p_i["ffn"], h, cfg, counts=c_i.get("moe"), pos=pos,
                    phase1=moe.Phase1(*ph1, route_cap))
            else:
                x, h, new_attn = _layer_decode_attn_head_jit(cfg)(
                    p_i, x, c_i["attn"], pos_t)
                f, moe_counts = moe_fn(p_i["ffn"], h, cfg,
                                       counts=c_i.get("moe"), pos=pos)
            return x + f, {"attn": new_attn, "moe": moe_counts}
        return _layer_decode_jit(cfg, kind)(p_i, x, c_i, pos_t)

    if "prologue" in params:
        pro = []
        for i in range(cfg.n_prologue):
            x, nc = layered_block(cfg.block_unit[0],
                                  take(params["prologue"], i), x,
                                  take(cache["prologue"], i))
            pro.append(nc)
        new_cache["prologue"] = restack(pro)

    per_step = []
    for i in range(cfg.n_repeats):
        new_slots = []
        for slot, kind in enumerate(cfg.block_unit):
            p_i = take(params["blocks"][slot], i)
            c_i = take(cache["slots"][slot], i)
            x, nc = layered_block(kind, p_i, x, c_i)
            new_slots.append(nc)
        if cfg.shared_attn_every:
            c_i = take(cache["slots"][-1], i)
            # step index is concrete here, so the fire test is plain Python
            if (i % cfg.shared_attn_every) == (cfg.shared_attn_every - 1):
                x, nc = _layer_decode_jit(cfg, "shared_attn")(
                    shared_p, x, c_i, pos_t)
            else:
                nc = c_i
            new_slots.append(nc)
        per_step.append(tuple(new_slots))
    new_cache["slots"] = tuple(
        restack([step[s] for step in per_step])
        for s in range(len(per_step[0])))

    logits = _final_logits_jit(cfg, False)(params["final_norm"],
                                           _unemb_param(params, cfg), x)
    return logits, new_cache


def prefill_layered(params: Params, tokens: jax.Array, cfg: ArchConfig, *,
                    max_seq: int, embeddings: Optional[jax.Array] = None,
                    impl: str = "chunked", cache_dtype=jnp.bfloat16,
                    moe_fn=None, route_ahead: bool = False,
                    kv_quant: Optional[str] = None,
                    attn_mask: Optional[AttnMaskSpec] = None):
    """Serving prefill, layer by layer: same function as :func:`prefill`
    but with the repeat loop unrolled in Python so a serving loop can
    interleave host work (two-phase MoE routing) between layers.  This is
    what lets prefill ride the *bucketed routed stream* instead of tracing
    the full ``E*C x T`` dispatch grid (the single-phase jit fallback).
    Each layer runs as a cached jitted step; ``moe_fn`` (signature of
    ``moe.apply_moe``) is injected at every attn+moe block with
    ``counts=None, pos=None`` -- a fresh sequence at position 0, exactly the
    fused prefill's routing state.  ``route_ahead=True`` fuses route
    phase 1 into each attn+moe layer's jitted attention step and passes the
    resulting ``moe.Phase1`` to ``moe_fn`` (see
    :func:`decode_step_layered`); values are identical either way."""
    pol = precision_policy(cfg.policy)
    cd = pol.compute_dtype
    x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    if embeddings is not None:
        x = jnp.concatenate([embeddings.astype(cd), x], axis=1)
    S_total = x.shape[1]
    shared_p = params.get("shared_attn")
    cache: Dict[str, Any] = {}
    take, restack = _tree_take, _tree_stack
    if route_ahead:
        route_cap = moe.dispatch_capacity(S_total, cfg, pos0=0)

    def layered_block(kind, p_i, x):
        if kind == "attn+moe" and moe_fn is not None:
            if route_ahead:
                x, h, new_attn, ph1 = _layer_prefill_attn_route_jit(
                    cfg, kind, max_seq, impl, route_cap, kv_quant,
                    attn_mask)(p_i, x)
                f, moe_counts = moe_fn(p_i["ffn"], h, cfg, counts=None,
                                       pos=None,
                                       phase1=moe.Phase1(*ph1, route_cap))
            else:
                x, h, new_attn = _layer_prefill_attn_head_jit(
                    cfg, kind, max_seq, impl, kv_quant, attn_mask)(p_i, x)
                f, moe_counts = moe_fn(p_i["ffn"], h, cfg, counts=None,
                                       pos=None)
            return x + f, {"attn": new_attn, "moe": moe_counts}
        return _layer_prefill_jit(cfg, kind, max_seq, impl, kv_quant,
                                  attn_mask)(p_i, x)

    if "prologue" in params:
        pro = []
        for i in range(cfg.n_prologue):
            x, nc = layered_block(cfg.block_unit[0],
                                  take(params["prologue"], i), x)
            pro.append(nc)
        cache["prologue"] = restack(pro)

    per_step = []
    for i in range(cfg.n_repeats):
        new_slots = []
        for slot, kind in enumerate(cfg.block_unit):
            x, nc = layered_block(kind, take(params["blocks"][slot], i), x)
            new_slots.append(nc)
        if cfg.shared_attn_every:
            # cache is collected every repeat (like the fused prefill); the
            # residual only advances on fire steps
            fire = (i % cfg.shared_attn_every) == (cfg.shared_attn_every - 1)
            y2, c2 = _layer_prefill_jit(cfg, "shared_attn", max_seq,
                                        impl, kv_quant, attn_mask)(shared_p, x)
            if fire:
                x = y2
            new_slots.append(c2)
        per_step.append(tuple(new_slots))
    cache["slots"] = tuple(
        restack([step[s] for step in per_step])
        for s in range(len(per_step[0])))

    logits = _final_logits_jit(cfg, True)(params["final_norm"],
                                          _unemb_param(params, cfg), x)
    cache = _cache_to_dtype(cache, cd, cache_dtype)
    return logits, cache, jnp.asarray(S_total, jnp.int32)
