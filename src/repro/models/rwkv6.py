"""RWKV-6 "Finch" mixer: attention-free, data-dependent per-channel decay.

Recurrence (per head, state S in R^{hd x hd}):
  S_t = diag(w_t) S_{t-1} + k_t (x) v_t
  y_t = r_t S_{t-1} + (r_t . (u (*) k_t)) v_t
with w_t = exp(-exp(w0 + lora(x~_t))) -- the *data-dependent decay* that is
the paper's headline feature. Chunked parallel form mirrors ssd_chunked (the
decay is a per-channel vector rather than a scalar per head); log-space
cumulative sums keep the decay divisions stable.

``rwkv_scan_ref`` is the sequential oracle for tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import init_rmsnorm, rmsnorm

LORA_R = 64
HEAD_DIM = 64


def init_rwkv(key, cfg: ArchConfig):
    d = cfg.d_model
    nh = d // HEAD_DIM
    ks = jax.random.split(key, 12)
    s = d ** -0.5
    return {
        # time-mix static lerp factors for r,k,v,g + the decay channel
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),
        "w_r": jax.random.normal(ks[0], (d, d), jnp.float32) * s,
        "w_k": jax.random.normal(ks[1], (d, d), jnp.float32) * s,
        "w_v": jax.random.normal(ks[2], (d, d), jnp.float32) * s,
        "w_g": jax.random.normal(ks[3], (d, d), jnp.float32) * s,
        "w_o": jax.random.normal(ks[4], (d, d), jnp.float32) * s,
        # data-dependent decay: w0 + lora
        "decay_base": -6.0 * jnp.ones((d,), jnp.float32),
        "decay_lora_a": jax.random.normal(ks[5], (d, LORA_R), jnp.float32) * s,
        "decay_lora_b": jax.random.normal(ks[6], (LORA_R, d), jnp.float32) * (LORA_R ** -0.5) * 0.1,
        "bonus_u": jax.random.normal(ks[7], (nh, HEAD_DIM), jnp.float32) * 0.1,
        "ln_x": init_rmsnorm(d),
        # channel mix
        "mu_c": 0.5 * jnp.ones((2, d), jnp.float32),
        "w_ck": jax.random.normal(ks[8], (d, cfg.d_ff), jnp.float32) * s,
        "w_cv": jax.random.normal(ks[9], (cfg.d_ff, d), jnp.float32) * (cfg.d_ff ** -0.5),
        "w_cr": jax.random.normal(ks[10], (d, d), jnp.float32) * s,
    }


def _token_shift(x, prev=None):
    """(B,T,d) -> previous-token stream; ``prev``: (B,1,d) decode carry."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def wkv_chunked(r, k, v, w_log, u, *, chunk: int = 32, s0=None):
    """Chunked WKV. r,k,v: (B,T,nh,hd); w_log: (B,T,nh,hd) (<0);
    u: (nh,hd). Returns (y (B,T,nh,hd), S_final (B,nh,hd,hd))."""
    B, T, nh, hd = r.shape
    pad = (-T) % chunk
    if pad:
        zp = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, zp), jnp.pad(k, zp), jnp.pad(v, zp)
        w_log = jnp.pad(w_log, zp)
    Tp = T + pad
    nc = Tp // chunk
    rc = r.reshape(B, nc, chunk, nh, hd)
    kc = k.reshape(B, nc, chunk, nh, hd)
    vc = v.reshape(B, nc, chunk, nh, hd)
    wc = w_log.reshape(B, nc, chunk, nh, hd)
    cum = jnp.cumsum(wc, axis=2)                     # inclusive log-decay sums

    # intra-chunk: y_i += sum_{j<i} (r_i*exp(cum_{i-1}-cum_j)) . k_j  v_j
    #   exp(cum_{i-1}-cum_j) = exp(cum_i - w_i - cum_j)
    # mid-chunk rescale: referencing both factors to cum[mid] bounds each
    # exponent by a *half*-chunk decay sum, keeping Q=128 inside f32 range
    # even at the decay clamp (exp(64) ~ 6e27 << f32 max). Validated against
    # the sequential oracle with clamp-saturating decays in tests.
    ri = rc * jnp.exp(cum - wc)                      # (B,nc,Q,nh,hd), exp<=0
    mid = cum[:, :, chunk // 2 : chunk // 2 + 1]     # (B,nc,1,nh,hd)
    ri_s = rc * jnp.exp(cum - wc - mid)
    kj_s = kc * jnp.exp(mid - cum)
    att = jnp.einsum("bciht,bcjht->bchij", ri_s, kj_s)  # (B,nc,nh,Q,Q)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    att = jnp.where(mask[None, None, None], att, 0.0)
    y = jnp.einsum("bchij,bcjhd->bcihd", att, vc)
    # diagonal bonus: (r_i . (u*k_i)) v_i
    diag = jnp.einsum("bciht,ht,bciht->bcih", rc, u, kc)
    y = y + diag[..., None] * vc

    # chunk-final states: S_c = diag(exp(cum_Q)) S_0-part + sum_j exp(cum_Q-cum_j) k_j (x) v_j
    decay_out = jnp.exp(cum[:, :, -1:, :, :] - cum)  # (B,nc,Q,nh,hd)
    S = jnp.einsum("bcjht,bcjhd->bchtd", kc * decay_out, vc)  # (B,nc,nh,hd,hd)
    w_tot = jnp.exp(cum[:, :, -1])                   # (B,nc,nh,hd)

    if s0 is None:
        s0 = jnp.zeros((B, nh, hd, hd), jnp.float32)

    def step(s, inp):
        wt, Sc = inp                                 # (B,nh,hd), (B,nh,hd,hd)
        s = s * wt[..., None] + Sc
        return s, s

    s_last, s_all = jax.lax.scan(
        step, s0, (w_tot.transpose(1, 0, 2, 3), S.transpose(1, 0, 2, 3, 4)))
    s_prev = jnp.concatenate([s0[None], s_all[:-1]], axis=0)
    s_prev = s_prev.transpose(1, 0, 2, 3, 4)         # (B,nc,nh,hd,hd)

    # inter-chunk: y_i += (r_i * exp(cum_{i-1})) S_prev
    y = y + jnp.einsum("bciht,bchtd->bcihd", ri, s_prev)
    return y.reshape(B, Tp, nh, hd)[:, :T], s_last


def apply_rwkv_time(p, x, cfg: ArchConfig, *, cache=None, chunk: int = 128,
                    collect: bool = False):
    """Time-mix half. ``cache``: dict(shift_t (B,1,d), wkv (B,nh,hd,hd)).
    ``collect`` returns the prefill-final cache. Returns (out, new_cache)."""
    B, T, d = x.shape
    nh = d // HEAD_DIM
    prev_t = cache["shift_t"] if cache is not None else None
    xx = _token_shift(x, prev_t)
    mix = lambda i: x + (xx - x) * p["mu"][i].astype(x.dtype)
    xr, xk, xv, xg, xw = (mix(i) for i in range(5))
    cd = x.dtype
    r = (xr @ p["w_r"].astype(cd)).reshape(B, T, nh, HEAD_DIM).astype(jnp.float32)
    k = (xk @ p["w_k"].astype(cd)).reshape(B, T, nh, HEAD_DIM).astype(jnp.float32)
    v = (xv @ p["w_v"].astype(cd)).reshape(B, T, nh, HEAD_DIM).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["w_g"].astype(cd))
    # data-dependent decay (the Finch mechanism). Clamped below so the
    # chunked factorization exp(-cum_j) stays within f32 range (the masked
    # i<j region of `att` is bounded by exp(chunk * clamp)).
    lora = jnp.tanh(xw.astype(jnp.float32) @ p["decay_lora_a"]) @ p["decay_lora_b"]
    w_log = -jnp.exp(p["decay_base"] + lora)         # (B,T,d) < 0
    w_log = jnp.maximum(w_log, -1.0)
    w_log = w_log.reshape(B, T, nh, HEAD_DIM)

    if cache is None:
        y, s_last = wkv_chunked(r, k, v, w_log, p["bonus_u"], chunk=chunk)
        new_cache = ({"wkv": s_last, "shift_t": x[:, -1:]} if collect else None)
    else:
        s0 = cache["wkv"]
        rt, kt, vt = r[:, 0], k[:, 0], v[:, 0]       # (B,nh,hd)
        y1 = jnp.einsum("bht,bhtd->bhd", rt, s0)
        bonus = jnp.einsum("bht,ht,bht->bh", rt, p["bonus_u"], kt)
        y = (y1 + bonus[..., None] * vt)[:, None]
        s_last = s0 * jnp.exp(w_log[:, 0])[..., None] + \
            jnp.einsum("bht,bhd->bhtd", kt, vt)
        new_cache = {"wkv": s_last, "shift_t": x[:, -1:]}

    y = y.reshape(B, T, d).astype(cd)
    y = rmsnorm(p["ln_x"], y, cfg.norm_eps) * g
    return y @ p["w_o"].astype(cd), new_cache


def apply_rwkv_channel(p, x, cfg: ArchConfig, *, cache=None,
                       collect: bool = False):
    """Channel-mix half (squared-relu FFN over token-shifted mix).
    ``cache``: dict(shift_c (B,1,d))."""
    cd = x.dtype
    prev_c = cache["shift_c"] if cache is not None else None
    xx = _token_shift(x, prev_c)
    xk2 = x + (xx - x) * p["mu_c"][0].astype(cd)
    xr2 = x + (xx - x) * p["mu_c"][1].astype(cd)
    kk = jnp.square(jax.nn.relu(xk2 @ p["w_ck"].astype(cd)))
    out = jax.nn.sigmoid(xr2 @ p["w_cr"].astype(cd)) * (kk @ p["w_cv"].astype(cd))
    new_cache = ({"shift_c": x[:, -1:]} if (cache is not None or collect)
                 else None)
    return out, new_cache


def rwkv_scan_ref(r, k, v, w_log, u, s0=None):
    """Sequential oracle for wkv_chunked (tests only)."""
    B, T, nh, hd = r.shape
    s = s0 if s0 is not None else jnp.zeros((B, nh, hd, hd), jnp.float32)

    def step(s, t_in):
        rt, kt, vt, wt = t_in
        y = jnp.einsum("bht,bhtd->bhd", rt, s) + \
            jnp.einsum("bht,ht,bht->bh", rt, u, kt)[..., None] * vt
        s = s * jnp.exp(wt)[..., None] + jnp.einsum("bht,bhd->bhtd", kt, vt)
        return s, y

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, w_log))
    s_last, ys = jax.lax.scan(step, s, xs)
    return ys.transpose(1, 0, 2, 3), s_last
