"""Mixture-of-Experts: prefix-stable routing + pluggable SU dispatch.

This is where the paper's technique is first-class in the LM stack: routing
tokens to experts *is* a sparse-dense product, and the layer is split into
the two stages that framing implies.

**Routing stage** (:func:`route_tokens`) -- prefix-stable by construction.
The slot of a token in its expert's queue is a pure function of the token's
own (batch row, position, expert) history: slots are assigned by cumsum
along the *sequence* dim per (row, expert), offset by an occupancy count
``counts[row, expert]`` carried across calls (the decode cache threads it),
and the keep/drop decision compares the slot against the *prefix* capacity

    C(t) = ceil((t + 1) / E * capacity_factor)

where ``t`` is the token's absolute position.  Because neither the slot nor
the capacity depends on which other rows share the batch or on how many
future tokens follow, a one-token decode step reproduces exactly the slot --
and the drop decision -- the same token gets inside a prefill.  (The old
formulation cumsummed over the flattened in-batch token stream with a
whole-batch capacity, so decode saw a different drop set than prefill;
see ROADMAP PR-2.)  Occupancy counts *all* routed tokens, kept or dropped,
so the queue position is a plain cumsum of the assignment one-hots.

**Dispatch stage** -- ``moe_dispatch="gather" | "bcsr"`` (ArchConfig field,
overridable via ``repro.parallel.context.MOE_DISPATCH`` or the ``dispatch=``
argument):

* ``"gather"`` -- SU indirection: the inverse index stream gathers token
  rows into dense (E, B, C, d) capacity tiles (``jnp.take_along_axis``).
* ``"bcsr"``   -- the dispatch matrix itself is materialized as a
  :class:`~repro.core.formats.BatchedBCSR` (one shared index stream, one
  0/1 block set per batch row) and run through
  ``repro.kernels.engine.shard_spmm_batched`` -- the SpMM Pallas kernel on
  the device mesh.  Under tracing (inside ``lax.scan``/``jit``) the block
  stream falls back to the full grid (data-dependent sparsity cannot change
  static shapes); eagerly it compacts to the union nonzero-block pattern.
  Tile sizes come from ``kernels.tuning`` (op ``"moe_dispatch"``).

Both backends produce bit-identical dispatch buffers (the BCSR path
multiplies by exact 0/1 blocks with f32 accumulation), so the backends are
interchangeable mid-deployment.  The grouped expert GEMM consumes dense
(E, B*C, d) tiles and combine gathers results back by the same index stream.

**Two-phase serving** (:func:`route_moe` / :func:`execute_moe`) -- the
route-then-compile split that keeps the bcsr stream sparse *under jit*:
phase 1 routes eagerly and compacts the dispatch stream to its union
nonzero-block pattern on host, padded to a power-of-two nnzb bucket
(``engine.stream_bucket``); phase 2 is a jit-compiled dispatch+FFN+combine
whose compile cache keys on the bucket, so recompiles are bounded while
the streamed work tracks the *routed* blocks, not the ``E*C x T`` grid.
``launch.serve.ServeLoop`` drives this per decode step.

Expert-parallel: the leading E dim of expert weights shards over the
"model" axis; the gather/scatter becomes an all-to-all under pjit.
"""
from __future__ import annotations

import functools
import time
import warnings
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import _pytree_dataclass
from repro.core.precision import QuantTensor, quantize_tensor
from repro.models.config import ArchConfig
from repro.models.layers import init_mlp, apply_mlp


def init_moe(key, cfg: ArchConfig):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    k_r, k_e, k_s = jax.random.split(key, 3)
    s = d ** -0.5
    n_w = 3 if cfg.mlp_type == "swiglu" else 2
    keys = jax.random.split(k_e, n_w)
    if cfg.mlp_type == "swiglu":
        experts = {
            "w_gate": jax.random.normal(keys[0], (E, d, ff), jnp.float32) * s,
            "w_up": jax.random.normal(keys[1], (E, d, ff), jnp.float32) * s,
            "w_down": jax.random.normal(keys[2], (E, ff, d), jnp.float32) * (ff ** -0.5),
        }
    else:
        experts = {
            "w_up": jax.random.normal(keys[0], (E, d, ff), jnp.float32) * s,
            "w_down": jax.random.normal(keys[1], (E, ff, d), jnp.float32) * (ff ** -0.5),
        }
    p = {"router": jax.random.normal(k_r, (d, E), jnp.float32) * s,
         "experts": experts}
    if cfg.moe_shared_expert:
        p["shared"] = init_mlp(k_s, cfg)
    return p


def _wcast(w, cd):
    """Weight accessor of the expert GEMMs: dequantize BlockQuant weights
    (narrow values * per-channel f32 scales) or plain-cast wide ones."""
    if isinstance(w, QuantTensor):
        return w.dequantize(cd)
    return w.astype(cd)


def _expert_ffn(experts, xe, mlp_type: str):
    """xe: (E, C, d) -> (E, C, d); batched over the expert dim (EP shards it)."""
    cd = xe.dtype
    if mlp_type == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, _wcast(experts["w_gate"], cd)))
        h = h * jnp.einsum("ecd,edf->ecf", xe, _wcast(experts["w_up"], cd))
    else:
        h = jnp.square(jax.nn.relu(
            jnp.einsum("ecd,edf->ecf", xe, _wcast(experts["w_up"], cd))))
    return jnp.einsum("ecf,efd->ecd", h, _wcast(experts["w_down"], cd))


def quantize_expert_weights(params, dtype, *, rounding: str = "nearest",
                            seed: int = 0):
    """Opt-in BlockQuant of the expert FFN weights (the serving memory hog:
    ``E`` copies of every MLP matrix).

    Each ``experts`` leaf ``(..., E, d_in, d_out)`` becomes a
    :class:`~repro.core.precision.QuantTensor` with one f32 scale per
    (expert, output channel) -- scales over the contraction axis ``-2``, so
    the quantization error of one input channel never leaks across output
    channels.  The negative axis makes the QuantTensor *slice-stable*: a
    repeat-stacked leaf ``(n_repeats, E, d_in, d_out)`` keeps a valid axis
    after ``lax.scan`` / ``_tree_take`` strip the leading dim.  Router /
    shared-expert / non-MoE params are untouched, and the QuantTensor
    leaves flow through ``execute_moe[_jit]`` / ``apply_moe`` transparently
    (pytree); :func:`_wcast` dequantizes at the einsum boundary.  Returns a
    new params dict (input unchanged)."""
    if "experts" not in params:
        raise ValueError(
            f"quantize_expert_weights: params has no 'experts' subtree "
            f"(keys: {sorted(params)})")
    out = dict(params)
    out["experts"] = {
        k: quantize_tensor(w, dtype, axis=-2, rounding=rounding, seed=seed)
        for k, w in params["experts"].items()}
    return out


def quantize_model_experts(params, dtype, *, rounding: str = "nearest",
                           seed: int = 0):
    """Model-level twin of :func:`quantize_expert_weights`: walk the stacked
    block slots (+ prologue) of a full ``model.init_params`` dict and
    quantize every attn+moe slot's expert weights.  Raises if the model has
    no MoE slot at all (a silent no-op would masquerade as a memory win)."""
    def q_slot(slot):
        if isinstance(slot, dict) and isinstance(slot.get("ffn"), dict) \
                and "experts" in slot["ffn"]:
            s = dict(slot)
            s["ffn"] = quantize_expert_weights(slot["ffn"], dtype,
                                               rounding=rounding, seed=seed)
            return s, True
        return slot, False

    out = dict(params)
    hit = False
    if "blocks" in params:
        new_slots = []
        for slot in params["blocks"]:
            s, h = q_slot(slot)
            hit |= h
            new_slots.append(s)
        out["blocks"] = tuple(new_slots)
    if "prologue" in params:
        s, h = q_slot(params["prologue"])
        hit |= h
        out["prologue"] = s
    if not hit:
        raise ValueError(
            "quantize_model_experts: no attn+moe slot with an 'experts' "
            "subtree found in params")
    return out


# ----------------------------------------------------------------- routing --

class Routing(NamedTuple):
    """Per-token routing decision (all leading dims (B, S))."""
    gate: jax.Array        # f32 top-1 router probability
    expert_id: jax.Array   # int32 assigned expert
    slot: jax.Array        # int32 absolute position in the (row, expert) queue
    within: jax.Array      # int32 queue position within THIS call (slot - base)
    keep: jax.Array        # bool  slot < prefix capacity at the token's position
    new_counts: jax.Array  # (B, E) int32 occupancy after this call
    logits: jax.Array      # (B, S, E) f32 router logits (for aux losses)


def prefix_capacity(t, n_experts: int, capacity_factor: float) -> jax.Array:
    """Per-(row, expert) queue capacity after ``t + 1`` tokens:
    ``ceil((t+1)/E * capacity_factor)``.  Traceable in ``t``; decode and
    prefill call it with the same absolute positions, so the keep sets are
    bit-identical (the multiply happens in f32 in both)."""
    t1 = (jnp.asarray(t, jnp.int32) + 1).astype(jnp.float32)
    return jnp.ceil(t1 * np.float32(capacity_factor / n_experts)).astype(jnp.int32)


def dispatch_capacity(S: int, cfg: ArchConfig, pos0=0) -> int:
    """Static capacity of the dispatch buffer for an S-token call starting at
    absolute position ``pos0``.  Kept tokens satisfy ``within < S`` and
    ``within <= slot < C(pos0 + S - 1)``, so the min of the two bounds is a
    safe buffer size; when ``pos0`` is traced (stepwise decode) only the
    S bound is static.  Uses the same f32 arithmetic as
    :func:`prefix_capacity` so the bound can never be under the keep test.
    Traced *and* per-row-vector ``pos0`` (continuous batching) both take
    the S bound -- the capacity must be one static int for the batch."""
    if not isinstance(pos0, (int, np.integer)):
        return max(1, S)
    cap = int(np.ceil(np.float32(pos0 + S)
                      * np.float32(cfg.capacity_factor / cfg.n_experts)))
    return max(1, min(S, cap))


def route_tokens(router: jax.Array, x: jax.Array, cfg: ArchConfig, *,
                 counts: Optional[jax.Array] = None, pos0=0) -> Routing:
    """Top-1 routing with prefix-stable slot assignment.

    x: (B, S, d); ``counts``: (B, E) int32 occupancy carried from previous
    calls on the same rows (None = fresh sequence); ``pos0``: absolute
    position of x[:, 0] -- an int / traced scalar shared by the whole
    batch, or a ``(B,)`` vector of per-row positions (continuous batching:
    each request slot sits at its own depth in its own sequence).  The
    decision for token (b, s) depends only on row b's tokens at positions
    <= pos0[b] + s, so it is identical to routing that row alone.
    """
    B, S, _ = x.shape
    E = cfg.n_experts
    logits = x.astype(jnp.float32) @ router.astype(jnp.float32)   # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_id = jax.lax.top_k(probs, 1)                     # top-1 per pool spec
    gate, expert_id = gate[..., 0], expert_id[..., 0].astype(jnp.int32)

    onehot = jax.nn.one_hot(expert_id, E, dtype=jnp.int32)        # (B, S, E)
    if counts is None:
        counts = jnp.zeros((B, E), jnp.int32)
    # queue position = prior same-(row, expert) tokens, kept OR dropped
    within = ((jnp.cumsum(onehot, axis=1) - onehot) * onehot).sum(-1)
    base = (counts[:, None, :] * onehot).sum(-1)                  # (B, S)
    slot = base + within
    t_abs = (jnp.asarray(pos0, jnp.int32)[..., None]
             + jnp.arange(S, dtype=jnp.int32))       # (S,) or (B, S)
    cap = prefix_capacity(t_abs, E, cfg.capacity_factor)
    keep = slot < (cap if cap.ndim == 2 else cap[None, :])
    new_counts = counts + onehot.sum(axis=1)
    return Routing(gate, expert_id, slot, within, keep, new_counts, logits)


# ---------------------------------------------------------------- dispatch --

def _dispatch_gather(xt: jax.Array, flat_slot: jax.Array, E: int, C: int):
    """SU indirection dispatch: inverse index stream + gather.

    xt: (B, S, d); flat_slot: (B, S) in [0, E*C] (E*C = dropped).
    Returns (E, B, C, d) capacity tiles."""
    B, S, d = xt.shape
    inv = jnp.full((B, E * C + 1), S, jnp.int32)
    inv = inv.at[jnp.arange(B)[:, None], flat_slot].set(
        jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S)),
        mode="drop")[:, : E * C]
    xt_pad = jnp.concatenate([xt, jnp.zeros((B, 1, d), xt.dtype)], axis=1)
    xe = jnp.take_along_axis(xt_pad, inv[..., None], axis=1)      # (B, E*C, d)
    return xe.reshape(B, E, C, d).transpose(1, 0, 2, 3)


def _dispatch_grid(S: int, E: int, C: int, bm: int, bk: int):
    """The padded block geometry of the (slot, token) dispatch matrix:
    (M, Mp, Sp, gm, gn).  Single source of truth shared by the traced
    full-grid path and the routed-stream builder -- these two must agree or
    the eager/traced/two-phase dispatch paths stop being bit-identical."""
    M = E * C
    Mp = -(-M // bm) * bm
    Sp = -(-S // bk) * bk
    return M, Mp, Sp, Mp // bm, Sp // bk


def _dispatch_matrix_tiles(flat_slot: jax.Array, S: int, E: int, C: int,
                           bm: int, bk: int, dtype):
    """(bm, bk)-tiled 0/1 dispatch matrix for the bcsr backends.

    Returns (tiles4 (B, gm, gn, bm, bk), Mp, Sp): the (slot, token) dispatch
    matrix per batch row, zero-padded to block multiples; dropped tokens
    write the slice-off row ``Mp`` so they vanish from every tile."""
    B = flat_slot.shape[0]
    M, Mp, Sp, gm, gn = _dispatch_grid(S, E, C, bm, bk)
    rows = jnp.where(flat_slot < M, flat_slot, Mp)
    disp = jnp.zeros((B, Mp + 1, Sp), dtype)
    disp = disp.at[jnp.arange(B)[:, None], rows,
                   jnp.arange(S, dtype=jnp.int32)[None, :]].set(1)[:, :Mp]
    return disp.reshape(B, gm, bm, gn, bk).transpose(0, 1, 3, 2, 4), Mp, Sp


def _build_routed_stream(flat_slot, S: int, E: int, C: int, bm: int,
                         bk: int, dtype, min_bucket: Optional[int] = None):
    """Compacted dispatch stream straight from *concrete* slots, host-side.

    The single construction site for the routed-stream semantics shared by
    the eager bcsr backend and phase 1 of the two-phase loop: union
    nonzero-block pattern over the batch, every-block-row-appears coverage
    (kernel contract, zero block at col 0), (row, col)-sorted stream.
    Cost is O(B*S + nnzb*bm*bk) -- it never touches the dense E*C x T
    grid, only the one (slot, token) entry each kept token contributes.

    ``min_bucket`` set (the two-phase path) pads the stream to its
    power-of-two bucket *here*, while everything is still host numpy --
    one device allocation/transfer at final size, instead of transferring
    exact-size then concatenating on device (``with_capacity``).  Pad
    entries repeat the last coordinate with zero blocks, same semantics.

    Returns (BatchedBCSR, nnzb_routed, nnzb_covered): data blocks before
    row coverage, and the covered (pre-bucket) stream length."""
    from repro.core.formats import BatchedBCSR
    from repro.kernels import engine

    fs = np.asarray(flat_slot)
    B = fs.shape[0]
    M, Mp, Sp, gm, gn = _dispatch_grid(S, E, C, bm, bk)
    if fs.size and (fs.min() < 0 or fs.max() > M):
        # Negative slots would silently wrap through numpy fancy indexing
        # into a *valid-looking* but corrupt stream; out-of-range positives
        # likewise.  A routed slot is in [0, M) or == M (dropped), full stop.
        raise ValueError(
            f"_build_routed_stream: flat_slot out of range "
            f"[{int(fs.min())}, {int(fs.max())}] vs dispatch grid M={M} "
            f"(corrupt routing output -- non-finite logits or a poisoned "
            f"occupancy cache upstream?)")
    b_idx, s_idx = np.nonzero(fs < M)        # kept tokens (dropped = M)
    slots = fs[b_idx, s_idx]
    keys = (slots // bm).astype(np.int64) * gn + s_idx // bk
    coords = np.unique(keys)                  # sorted == (row, col)-sorted
    nnzb_routed = len(coords)
    present = np.zeros(gm, bool)
    present[(coords // gn).astype(np.int32)] = True
    coords = np.union1d(coords,
                        np.nonzero(~present)[0].astype(np.int64) * gn)
    nnzb_covered = len(coords)
    idx = np.searchsorted(coords, keys)       # before any bucket padding
    cap = nnzb_covered
    if min_bucket is not None:
        cap = engine.stream_bucket(nnzb_covered, minimum=min_bucket)
        coords = np.concatenate(
            [coords, np.full(cap - nnzb_covered, coords[-1])])
    brows = (coords // gn).astype(np.int32)
    bcols = (coords % gn).astype(np.int32)
    blocks = np.zeros((B, cap, bm, bk), np.dtype(dtype))
    blocks[b_idx, idx, slots % bm, s_idx % bk] = 1
    indptr = np.zeros(gm + 1, np.int32)
    np.cumsum(np.bincount(brows, minlength=gm), out=indptr[1:])
    stream = BatchedBCSR(indptr=jnp.asarray(indptr),
                         block_rows=jnp.asarray(brows),
                         block_cols=jnp.asarray(bcols),
                         blocks=jnp.asarray(blocks),
                         shape=(B, Mp, Sp), block=(bm, bk))
    return stream, nnzb_routed, nnzb_covered


def _dispatch_bcsr(xt: jax.Array, flat_slot: jax.Array, E: int, C: int):
    """Dispatch-as-SpMM: per-row 0/1 dispatch matrices as one BatchedBCSR
    (shared index stream) through the sharded SpMM Pallas kernel.

    Eagerly the stream compacts to the union nonzero-block pattern; under
    tracing the pattern is the full grid (static shapes) -- serving callers
    avoid that cost by routing eagerly first (:func:`route_moe`) and running
    the compiled phase on the compacted stream (:func:`execute_moe`).
    Returns (E, B, C, d), bit-identical to :func:`_dispatch_gather` (0/1
    blocks, f32 accumulate).
    """
    from repro.core.formats import BatchedBCSR
    from repro.kernels import engine, tuning

    B, S, d = xt.shape
    tiles = tuning.moe_dispatch_tiles(d, xt.dtype)
    bm, bk = tiles["block"]
    M = E * C

    if isinstance(flat_slot, jax.core.Tracer):
        # static shapes under jit/scan: the stream is the full grid, block
        # values come from the (traced) dense dispatch matrix.  The index
        # stream stays host-side numpy: it is routing-independent here and
        # the engine inspects it with numpy before the call.
        tiles4, Mp, Sp = _dispatch_matrix_tiles(flat_slot, S, E, C, bm, bk,
                                                xt.dtype)
        gm, gn = Mp // bm, Sp // bk
        brows, bcols = np.nonzero(np.ones((gm, gn), bool))
        indptr = np.zeros(gm + 1, np.int32)
        np.cumsum(np.bincount(brows, minlength=gm), out=indptr[1:])
        ab = BatchedBCSR(indptr=indptr,
                         block_rows=brows.astype(np.int32),
                         block_cols=bcols.astype(np.int32),
                         blocks=tiles4[:, brows, bcols],
                         shape=(B, Mp, Sp), block=(bm, bk))
    else:
        ab, _, _ = _build_routed_stream(flat_slot, S, E, C, bm, bk,
                                        xt.dtype)
        Sp = ab.shape[2]
    xt_p = jnp.pad(xt, ((0, 0), (0, Sp - S), (0, 0)))
    out = engine.shard_spmm_batched(ab, xt_p, bn=tiles["bn"],
                                    nt=tiles["nt"],
                                    out_dtype=xt.dtype)      # (B, Mp, d)
    return out[:, :M].reshape(B, E, C, d).transpose(1, 0, 2, 3)


def _dispatch_stream(xt: jax.Array, stream, E: int, C: int):
    """Phase-2 dispatch: a pre-built (route_moe) BatchedBCSR stream through
    the trace-safe engine entry.  Safe under jit -- the index arrays are
    traced arguments, so the compile cache keys on the *bucketed* stream
    shape, never on the concrete routing."""
    from repro.kernels import engine, tuning

    B, S, d = xt.shape
    _, Mp, Sp = stream.shape
    tiles = tuning.moe_dispatch_tiles(d, xt.dtype)
    xt_p = jnp.pad(xt, ((0, 0), (0, Sp - S), (0, 0)))
    out = engine.shard_spmm_batched_stream(stream, xt_p, bn=tiles["bn"],
                                           nt=tiles["nt"],
                                           out_dtype=xt.dtype)  # (B, Mp, d)
    M = E * C
    return out[:, :M].reshape(B, E, C, d).transpose(1, 0, 2, 3)


def _combine_gather(yt: jax.Array, flat_slot: jax.Array, gate: jax.Array,
                    keep: jax.Array, E: int, C: int):
    """Gather each token's expert output back by its own index; dropped
    tokens contribute zero.  yt: (B, E*C, d) -> (B, S, d)."""
    B = yt.shape[0]
    d = yt.shape[-1]
    yt_pad = jnp.concatenate([yt, jnp.zeros((B, 1, d), yt.dtype)], axis=1)
    back = jnp.take_along_axis(
        yt_pad, jnp.minimum(flat_slot, E * C)[..., None], axis=1)
    return back * (gate * keep).astype(back.dtype)[..., None]


# --------------------------------------------------------------- the layer --

def apply_moe(p, x, cfg: ArchConfig, *, counts: Optional[jax.Array] = None,
              pos=None, groups: Optional[int] = None,
              dispatch: Optional[str] = None):
    """x: (B, S, d) -> ((B, S, d), new_counts (B, E) int32).

    ``counts``/``pos`` thread the routing state for stepwise decode: pass the
    previous call's ``new_counts`` and the absolute position of x[:, 0] and a
    one-token step reproduces the prefill slot and drop decision bit-for-bit.
    Training/prefill callers pass neither (fresh sequence at position 0) and
    may discard the returned counts.

    ``dispatch`` selects the backend ("gather" | "bcsr"); default is
    ``context.MOE_DISPATCH`` then ``cfg.moe_dispatch``.

    Routing is per batch row, so under dp sharding of B the cumsum stays
    shard-local and the only cross-shard movement is the (E, B, C, d)
    dispatch -- the EP all-to-all.  ``groups`` (or ``context.MOE_GROUPS``)
    declares how many row groups the data axes expect; when it does not
    divide B the dispatch buffer cannot align with the data shards and the
    layer warns (raises under ``cfg.moe_strict_dispatch``) instead of
    silently falling back to an unaligned layout.
    """
    from repro.parallel import context as pctx
    from repro.parallel.sharding import constrain

    B, S, d = x.shape
    E = cfg.n_experts

    if pctx.MOE_IMPL == "shard_map" and pctx.MESH is not None:
        # train-only path: each (row, sequence-shard) chunk routes locally,
        # occupancy is NOT threaded across calls, and dispatch is always the
        # gather formulation.  A caller carrying routing state (decode) or
        # requesting the bcsr backend would silently lose prefix stability,
        # so that is an error in spirit -- surface it.
        backend = dispatch or pctx.MOE_DISPATCH or cfg.moe_dispatch
        if counts is not None or pos is not None or backend != "gather":
            msg = ("apply_moe: the shard_map impl is train-only -- it does "
                   "not thread routing occupancy (counts/pos) and only "
                   "supports moe_dispatch='gather'; decode and bcsr callers "
                   "must use the pjit impl.")
            if cfg.moe_strict_dispatch:
                raise ValueError(msg)
            warnings.warn(msg, RuntimeWarning, stacklevel=2)
        from repro.models.moe_shard_map import apply_moe_shard_map
        from repro.parallel.sharding import FSDP
        dp_axes = tuple(a for a in FSDP if a in pctx.MESH.axis_names)
        dp_axes = dp_axes if len(dp_axes) > 1 else dp_axes[0]
        out = apply_moe_shard_map(p, x, cfg, pctx.MESH, dp_axes=dp_axes,
                                  tp_axis="model")
        new_counts = counts if counts is not None else jnp.zeros((B, E), jnp.int32)
        return out, new_counts

    _check_groups(B, cfg, groups or pctx.MOE_GROUPS, "apply_moe")

    pos0 = 0 if pos is None else pos
    r = route_tokens(p["router"], x, cfg, counts=counts, pos0=pos0)
    C = dispatch_capacity(S, cfg, pos0=pos0)

    # --- SU dispatch: index stream (expert*C + within) per row -------------
    flat_slot = jnp.where(r.keep, r.expert_id * C + r.within, E * C)
    backend = dispatch or pctx.MOE_DISPATCH or cfg.moe_dispatch
    if backend == "bcsr":
        xe = _dispatch_bcsr(x, flat_slot, E, C)
    elif backend == "gather":
        xe = _dispatch_gather(x, flat_slot, E, C)
    else:
        raise ValueError(f"unknown moe_dispatch backend {backend!r}")
    out = _moe_tail(p, x, xe, r.gate, r.keep, flat_slot, cfg, E, C)
    return out, r.new_counts


def _check_groups(B: int, cfg: ArchConfig, G: Optional[int], who: str):
    if G and B % G != 0:
        msg = (f"{who}: {G} dispatch group(s) requested but the batch "
               f"dim B={B} is not divisible; the (E, B, C, d) dispatch "
               "buffer cannot align with the data shards and falls back to "
               "an ungrouped layout (extra resharding under pjit).")
        if cfg.moe_strict_dispatch:
            raise ValueError(msg)
        warnings.warn(msg, RuntimeWarning, stacklevel=3)


def _moe_tail(p, x, xe, gate, keep, flat_slot, cfg: ArchConfig, E: int,
              C: int):
    """Expert FFN + combine (+ shared expert): everything downstream of the
    dispatch buffer.  Shared verbatim by :func:`apply_moe` and the two-phase
    :func:`execute_moe`, so the phases can never drift from the fused layer.
    """
    from repro.parallel import context as pctx
    from repro.parallel.sharding import constrain

    B, S, d = x.shape
    if pctx.MOE_SPEC is not None:
        xe = constrain(xe, pctx.MOE_SPEC)                 # EP all-to-all

    ye = _expert_ffn(p["experts"], xe.reshape(E, B * C, d),
                     cfg.mlp_type).reshape(E, B, C, d)

    # --- SU combine: inverse all-to-all + gather back by the same stream ---
    # Constrain BACK to the dispatch (row-sharded) layout before the gather:
    # each token's result lives on exactly one expert shard, so the reshard is
    # an all-to-all; gathering straight from the EP layout instead makes GSPMD
    # emit a full-activation all-reduce per layer (measured: 5.4 GB -> 34 MB
    # per layer on llama4-scout train_4k).
    yt = ye.transpose(1, 0, 2, 3).reshape(B, E * C, d)
    if pctx.MOE_COMBINE_SPEC is not None:
        yt = constrain(yt, pctx.MOE_COMBINE_SPEC)
    out = _combine_gather(yt, flat_slot, gate, keep, E, C)

    if cfg.moe_shared_expert:
        out = out + apply_mlp(p["shared"], x.reshape(B * S, d),
                              cfg).reshape(B, S, d)
    return out


# ------------------------------------------------- two-phase serving API --

def route_phase1(router, x, cfg: ArchConfig, counts, pos0, capacity: int):
    """Traceable phase-1 body: router matmul + softmax/top-k + the
    prefix-stable slot cumsums, returning only the small per-token routing
    arrays -- never the hidden state.  Standalone it is jitted as
    :func:`_route_phase1_jit`; the pipelined serving path instead inlines it
    into the model's fused attention+route layer programs
    (``model._layer_*_attn_route_jit``) so the router output of a layer is
    dispatched one program ahead of the host route stage."""
    r = route_tokens(router, x, cfg, counts=counts, pos0=pos0)
    flat_slot = jnp.where(r.keep, r.expert_id * capacity + r.within,
                          cfg.n_experts * capacity)
    return r.gate, r.keep, r.new_counts, flat_slot


@functools.partial(jax.jit, static_argnames=("cfg", "capacity"))
def _route_phase1_jit(router, x, cfg: ArchConfig, counts, pos0, capacity):
    """The compiled half of phase 1: :func:`route_phase1` as one fused
    program instead of an op-by-op eager chain.  ``pos0`` rides as a traced
    scalar so every decode step reuses one compiled program; only the token
    shape and the static dispatch capacity key the cache.  The host-side
    remainder of phase 1 (stream compaction) needs the *values*, which it
    reads off the returned concrete arrays (:func:`plan_from_phase1`)."""
    return route_phase1(router, x, cfg, counts, pos0, capacity)


class Phase1(NamedTuple):
    """Phase-1 routing outputs plus the static dispatch capacity their slot
    encoding assumed.  Produced by :func:`_route_phase1_jit` (via
    :func:`route_moe`) or by the model's fused attention+route layer
    programs; consumed by :func:`plan_from_phase1`."""
    gate: jax.Array        # (B, S) f32 top-1 router probability
    keep: jax.Array        # (B, S) bool prefix-capacity keep set
    new_counts: jax.Array  # (B, E) int32 occupancy after this call
    flat_slot: jax.Array   # (B, S) int32 in [0, E*C]  (E*C = dropped)
    capacity: int          # static dispatch capacity C the slots encode


@_pytree_dataclass(static=("capacity", "backend"))
class MoEPlan:
    """Phase-1 output of the two-phase route-then-compile serving loop.

    Carries exactly what phase 2 consumes -- not the full
    :class:`Routing` (its logits / slot / expert-id arrays are dead weight
    in the compiled step and would ride the host->device argument path
    every decode step).  Array fields are pytree children, so a
    jit-compiled :func:`execute_moe` takes them as *traced arguments*; the
    static aux -- the dispatch capacity ``C`` and the backend name -- plus
    the (bucketed) stream shape are all that key the compile cache.  Two
    plans with the same token shape, capacity, and nnzb bucket therefore
    reuse one compiled program no matter how differently their tokens
    routed."""

    gate: jax.Array          # (B, S) f32 top-1 router probability
    keep: jax.Array          # (B, S) bool prefix-capacity keep set
    new_counts: jax.Array    # (B, E) int32 occupancy after this call
    flat_slot: jax.Array     # (B, S) int32 in [0, E*C]  (E*C = dropped)
    stream: Optional[object]  # BatchedBCSR dispatch stream ("bcsr") | None
    capacity: int            # static per-(row, expert) dispatch capacity C
    backend: str             # "gather" | "bcsr"


def route_moe(p, x, cfg: ArchConfig, *, counts: Optional[jax.Array] = None,
              pos=None, dispatch: Optional[str] = None,
              groups: Optional[int] = None) -> Tuple[MoEPlan, dict]:
    """Phase 1: route on a *concrete* ``x``, materialize the dispatch stream.

    The router matmul + slot cumsums run as one jit-compiled program
    (:func:`_route_phase1_jit`; ``pos0`` traced, so a decode phase compiles
    it once) and, for the "bcsr" backend, the 0/1 dispatch matrix is then
    compacted to its union nonzero-block stream on host -- the thing tracing
    fundamentally cannot do, because data-dependent sparsity cannot produce
    static shapes.
    The stream is then padded to its power-of-two nnzb bucket
    (``engine.stream_bucket``, floor from the ``"moe_dispatch"`` autotune
    row), so the phase-2 compile cache sees a bounded set of stream shapes.

    Returns ``(plan, info)``: ``plan`` feeds :func:`execute_moe` /
    :func:`execute_moe_jit`; ``info`` is host-side stats -- ``nnzb_routed``
    (data blocks in the union pattern), ``nnzb_covered`` (+ the kernel's
    every-row-appears coverage blocks), ``nnzb_stream`` (after bucketing),
    ``grid_nnzb`` (what the single-phase jit fallback would stream), and
    ``bucket``.
    """
    from repro.parallel import context as pctx
    from repro.kernels import tuning

    if isinstance(x, jax.core.Tracer):
        raise TypeError(
            "route_moe is the eager phase of the two-phase serving loop; "
            "call it outside jit and feed its plan to execute_moe (the "
            "compiled phase). Tracing the router would force the dispatch "
            "stream back to the full grid.")
    backend = dispatch or pctx.MOE_DISPATCH or cfg.moe_dispatch
    if backend not in ("gather", "bcsr"):
        raise ValueError(f"unknown moe_dispatch backend {backend!r}")
    B, S, d = x.shape
    E = cfg.n_experts
    _check_groups(B, cfg, groups or pctx.MOE_GROUPS, "route_moe")

    # concrete by contract: an int, or an int (B,) vector under continuous
    # batching (per-row positions; the dispatch capacity then takes the
    # position-independent S bound)
    if pos is None:
        pos0 = 0
    elif np.ndim(pos) == 0:
        pos0 = int(pos)
    else:
        pos0 = np.asarray(pos, np.int32)
    C = dispatch_capacity(S, cfg, pos0=pos0)
    # router + slot assignment run as ONE jitted program (pos0 traced, so a
    # whole decode phase reuses a single compile); the stream compaction
    # stays host-side (plan_from_phase1) -- the data-dependent step jit
    # cannot do.
    gate, keep, new_counts, flat_slot = _route_phase1_jit(
        p["router"], x, cfg, counts, jnp.asarray(pos0, jnp.int32), C)
    return plan_from_phase1(Phase1(gate, keep, new_counts, flat_slot, C),
                            cfg, dispatch=backend, dtype=x.dtype)


def plan_from_phase1(phase1: Phase1, cfg: ArchConfig, *,
                     dispatch: Optional[str] = None,
                     dtype=jnp.float32) -> Tuple[MoEPlan, dict]:
    """The host half of phase 1: fetch the ``(B, S)`` slot stream -- the
    ONLY device->host transfer; the hidden state never crosses -- compact it
    to the union nonzero-block :class:`BatchedBCSR` stream, and pad to its
    power-of-two nnzb bucket.  Shared by :func:`route_moe` (which computes
    phase 1 itself) and the pipelined serving loop (which receives phase 1
    from the model's fused attention+route layer program, dispatched a
    program ahead so the routing arrays are already materializing when the
    host arrives here).

    ``info`` carries the stream accounting of :func:`route_moe` plus the
    timing split the serving loop's phase attribution wants: ``wait_s``
    (time blocked fetching the slot stream off the device -- in pipelined
    mode this is the window that overlaps the in-flight execute of the
    previous layer) and ``host_s`` (pure host compaction/bucketing work)."""
    from repro.parallel import context as pctx
    from repro.kernels import tuning

    backend = dispatch or pctx.MOE_DISPATCH or cfg.moe_dispatch
    if backend not in ("gather", "bcsr"):
        raise ValueError(f"unknown moe_dispatch backend {backend!r}")
    gate, keep, new_counts, flat_slot, C = phase1
    S = flat_slot.shape[1]
    E = cfg.n_experts
    stream = None
    info = {"backend": backend, "capacity": C, "tokens": S,
            "wait_s": 0.0, "host_s": 0.0}
    if backend == "bcsr":
        t0 = time.monotonic()
        fs = np.asarray(flat_slot)      # (B, S) int32: the whole fetch
        t1 = time.monotonic()
        tiles = tuning.moe_dispatch_tiles(cfg.d_model, dtype)
        bm, bk = tiles["block"]
        stream, nnzb_routed, nnzb_covered = _build_routed_stream(
            fs, S, E, C, bm, bk, dtype, min_bucket=tiles["min_bucket"])
        gm, gn = stream.grid_shape
        info.update(nnzb_routed=nnzb_routed, nnzb_covered=nnzb_covered,
                    nnzb_stream=stream.nnzb, grid_nnzb=gm * gn,
                    bucket=stream.nnzb, block=(bm, bk),
                    wait_s=t1 - t0, host_s=time.monotonic() - t1)
    plan = MoEPlan(gate=gate, keep=keep, new_counts=new_counts,
                   flat_slot=flat_slot, stream=stream, capacity=C,
                   backend=backend)
    return plan, info


def execute_moe(p, x, plan: MoEPlan, cfg: ArchConfig):
    """Phase 2: dispatch + expert FFN + combine from a phase-1 plan.

    Pure and jit-friendly: all data-dependence is frozen into ``plan``'s
    arrays, whose shapes are bucketed, so compiling this (see
    :func:`execute_moe_jit`) retraces only per (token shape, capacity,
    nnzb-bucket) -- never per routing pattern.  Bit-identical to
    ``apply_moe(..., dispatch=plan.backend)`` on the same inputs: the
    dispatch buffer is built from the same 0/1 blocks and everything
    downstream is the shared :func:`_moe_tail`."""
    E, C = cfg.n_experts, plan.capacity
    if plan.backend == "bcsr":
        xe = _dispatch_stream(x, plan.stream, E, C)
    else:
        xe = _dispatch_gather(x, plan.flat_slot, E, C)
    out = _moe_tail(p, x, xe, plan.gate, plan.keep, plan.flat_slot, cfg, E,
                    C)
    return out, plan.new_counts


execute_moe_jit = functools.partial(jax.jit, static_argnames=("cfg",))(
    execute_moe)


def load_balance_loss(logits: jax.Array, expert_id: jax.Array, E: int):
    """Switch-style auxiliary loss (fraction-routed x mean-prob)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    frac = jnp.mean(jax.nn.one_hot(expert_id, E, dtype=jnp.float32), axis=0)
    mean_p = jnp.mean(probs, axis=0)
    return E * jnp.sum(frac * mean_p)
