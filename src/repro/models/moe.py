"""Mixture-of-Experts with SU-indirection dispatch (Llama-4 style).

This is where the paper's technique is first-class in the LM stack: routing
tokens to experts *is* a sparse-dense product. The router's expert-assignment
indices form the SU index stream; dispatch gathers token rows by index
(`indirect_gather`), the grouped expert GEMM consumes dense (E, C, d) tiles,
and combine scatters results back (`indirect_scatter_add`). The block-sparse
formulation (BCSR over the dispatch matrix) runs on the SpMM Pallas kernel in
``benchmarks/bench_moe.py``.

Capacity-based dropless-approx routing (Switch-style): per-expert capacity
C = ceil(T/E * capacity_factor); overflow tokens are dropped (contribute
zero), standard at scale. Expert-parallel: the leading E dim of expert
weights shards over the "model" axis; the gather/scatter becomes an
all-to-all under pjit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.su import indirect_gather
from repro.models.config import ArchConfig
from repro.models.layers import init_mlp, apply_mlp


def init_moe(key, cfg: ArchConfig):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    k_r, k_e, k_s = jax.random.split(key, 3)
    s = d ** -0.5
    n_w = 3 if cfg.mlp_type == "swiglu" else 2
    keys = jax.random.split(k_e, n_w)
    if cfg.mlp_type == "swiglu":
        experts = {
            "w_gate": jax.random.normal(keys[0], (E, d, ff), jnp.float32) * s,
            "w_up": jax.random.normal(keys[1], (E, d, ff), jnp.float32) * s,
            "w_down": jax.random.normal(keys[2], (E, ff, d), jnp.float32) * (ff ** -0.5),
        }
    else:
        experts = {
            "w_up": jax.random.normal(keys[0], (E, d, ff), jnp.float32) * s,
            "w_down": jax.random.normal(keys[1], (E, ff, d), jnp.float32) * (ff ** -0.5),
        }
    p = {"router": jax.random.normal(k_r, (d, E), jnp.float32) * s,
         "experts": experts}
    if cfg.moe_shared_expert:
        p["shared"] = init_mlp(k_s, cfg)
    return p


def _expert_ffn(experts, xe, mlp_type: str):
    """xe: (E, C, d) -> (E, C, d); batched over the expert dim (EP shards it)."""
    cd = xe.dtype
    if mlp_type == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, experts["w_gate"].astype(cd)))
        h = h * jnp.einsum("ecd,edf->ecf", xe, experts["w_up"].astype(cd))
    else:
        h = jnp.square(jax.nn.relu(
            jnp.einsum("ecd,edf->ecf", xe, experts["w_up"].astype(cd))))
    return jnp.einsum("ecf,efd->ecd", h, experts["w_down"].astype(cd))


def apply_moe(p, x, cfg: ArchConfig, *, groups: int = None):
    """x: (B, S, d) -> (B, S, d). Top-1 routing (per pool spec) w/ capacity.

    Grouped dispatch: tokens are viewed as (G, T/G) where G matches the data
    shards; routing slots are computed *within* each group so the cumsum
    stays shard-local, and the only cross-shard movement is the (E, G, Cg, d)
    dispatch -- the EP all-to-all. (The naive global-cumsum formulation
    serializes the whole token stream through one device; measured in
    EXPERIMENTS.md SPerf.)
    """
    from repro.parallel import context as pctx
    from repro.parallel.sharding import constrain

    if pctx.MOE_IMPL == "shard_map" and pctx.MESH is not None:
        from repro.models.moe_shard_map import apply_moe_shard_map
        from repro.parallel.sharding import FSDP
        dp_axes = tuple(a for a in FSDP if a in pctx.MESH.axis_names)
        dp_axes = dp_axes if len(dp_axes) > 1 else dp_axes[0]
        return apply_moe_shard_map(p, x, cfg, pctx.MESH, dp_axes=dp_axes,
                                   tp_axis="model")

    B, S, d = x.shape
    E = cfg.n_experts
    T = B * S
    G = groups or pctx.MOE_GROUPS or 1
    if T % G or (T // G) < 1:
        G = 1
    Tg = T // G
    Cg = max(1, int(Tg / E * cfg.capacity_factor))
    xt = x.reshape(G, Tg, d)

    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)               # (G, Tg, E)
    gate, expert_id = jax.lax.top_k(probs, 1)             # top-1 per pool spec
    gate, expert_id = gate[..., 0], expert_id[..., 0]     # (G, Tg)

    # Slot within the (group, expert) queue; overflow tokens drop (std. at
    # scale). Cumsum is per-group => shard-local under dp sharding of G.
    onehot = jax.nn.one_hot(expert_id, E, dtype=jnp.int32)       # (G, Tg, E)
    pos_in_e = (jnp.cumsum(onehot, axis=1) - 1) * onehot
    slot = pos_in_e.sum(axis=-1)                                  # (G, Tg)
    keep = slot < Cg

    # --- SU dispatch: index stream (expert*Cg + slot) per group ------------
    flat_slot = jnp.where(keep, expert_id * Cg + slot, E * Cg)    # drop -> pad
    inv = jnp.full((G, E * Cg + 1), Tg, jnp.int32)
    inv = inv.at[jnp.arange(G)[:, None], flat_slot].set(
        jnp.broadcast_to(jnp.arange(Tg, dtype=jnp.int32), (G, Tg)),
        mode="drop")[:, : E * Cg]
    xt_pad = jnp.concatenate([xt, jnp.zeros((G, 1, d), xt.dtype)], axis=1)
    xe = jnp.take_along_axis(xt_pad, inv[..., None], axis=1)      # (G, E*Cg, d)
    xe = xe.reshape(G, E, Cg, d).transpose(1, 0, 2, 3)            # (E, G, Cg, d)
    if pctx.MOE_SPEC is not None:
        xe = constrain(xe, pctx.MOE_SPEC)                         # EP all-to-all

    ye = _expert_ffn(p["experts"], xe.reshape(E, G * Cg, d),
                     cfg.mlp_type).reshape(E, G, Cg, d)

    # --- SU combine: inverse all-to-all + gather back by the same stream ---
    # Constrain BACK to the dispatch (group-sharded) layout before the gather:
    # each token's result lives on exactly one expert shard, so the reshard is
    # an all-to-all; gathering straight from the EP layout instead makes GSPMD
    # emit a full-activation all-reduce per layer (measured: 5.4 GB -> 34 MB
    # per layer on llama4-scout train_4k).
    ye = ye.transpose(1, 0, 2, 3).reshape(G, E * Cg, d)
    if pctx.MOE_COMBINE_SPEC is not None:
        ye = constrain(ye, pctx.MOE_COMBINE_SPEC)
    ye_pad = jnp.concatenate([ye, jnp.zeros((G, 1, d), ye.dtype)], axis=1)
    back = jnp.take_along_axis(
        ye_pad, jnp.minimum(flat_slot, E * Cg)[..., None], axis=1)
    out = back * (gate * keep).astype(back.dtype)[..., None]

    if cfg.moe_shared_expert:
        out = out + apply_mlp(p["shared"], xt.reshape(T, d), cfg).reshape(G, Tg, d)
    return out.reshape(B, S, d)


def load_balance_loss(logits: jax.Array, expert_id: jax.Array, E: int):
    """Switch-style auxiliary loss (fraction-routed x mean-prob)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    frac = jnp.mean(jax.nn.one_hot(expert_id, E, dtype=jnp.float32), axis=0)
    mean_p = jnp.mean(probs, axis=0)
    return E * jnp.sum(frac * mean_p)
