"""Mixture-of-Experts: prefix-stable routing + pluggable SU dispatch.

This is where the paper's technique is first-class in the LM stack: routing
tokens to experts *is* a sparse-dense product, and the layer is split into
the two stages that framing implies.

**Routing stage** (:func:`route_tokens`) -- prefix-stable by construction.
The slot of a token in its expert's queue is a pure function of the token's
own (batch row, position, expert) history: slots are assigned by cumsum
along the *sequence* dim per (row, expert), offset by an occupancy count
``counts[row, expert]`` carried across calls (the decode cache threads it),
and the keep/drop decision compares the slot against the *prefix* capacity

    C(t) = ceil((t + 1) / E * capacity_factor)

where ``t`` is the token's absolute position.  Because neither the slot nor
the capacity depends on which other rows share the batch or on how many
future tokens follow, a one-token decode step reproduces exactly the slot --
and the drop decision -- the same token gets inside a prefill.  (The old
formulation cumsummed over the flattened in-batch token stream with a
whole-batch capacity, so decode saw a different drop set than prefill;
see ROADMAP PR-2.)  Occupancy counts *all* routed tokens, kept or dropped,
so the queue position is a plain cumsum of the assignment one-hots.

**Dispatch stage** -- ``moe_dispatch="gather" | "bcsr"`` (ArchConfig field,
overridable via ``repro.parallel.context.MOE_DISPATCH`` or the ``dispatch=``
argument):

* ``"gather"`` -- SU indirection: the inverse index stream gathers token
  rows into dense (E, B, C, d) capacity tiles (``jnp.take_along_axis``).
* ``"bcsr"``   -- the dispatch matrix itself is materialized as a
  :class:`~repro.core.formats.BatchedBCSR` (one shared index stream, one
  0/1 block set per batch row) and run through
  ``repro.kernels.engine.shard_spmm_batched`` -- the SpMM Pallas kernel on
  the device mesh.  Under tracing (inside ``lax.scan``/``jit``) the block
  stream falls back to the full grid (data-dependent sparsity cannot change
  static shapes); eagerly it compacts to the union nonzero-block pattern.
  Tile sizes come from ``kernels.tuning`` (op ``"moe_dispatch"``).

Both backends produce bit-identical dispatch buffers (the BCSR path
multiplies by exact 0/1 blocks with f32 accumulation), so the backends are
interchangeable mid-deployment.  The grouped expert GEMM consumes dense
(E, B*C, d) tiles and combine gathers results back by the same index stream.

Expert-parallel: the leading E dim of expert weights shards over the
"model" axis; the gather/scatter becomes an all-to-all under pjit.
"""
from __future__ import annotations

import warnings
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models.layers import init_mlp, apply_mlp


def init_moe(key, cfg: ArchConfig):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    k_r, k_e, k_s = jax.random.split(key, 3)
    s = d ** -0.5
    n_w = 3 if cfg.mlp_type == "swiglu" else 2
    keys = jax.random.split(k_e, n_w)
    if cfg.mlp_type == "swiglu":
        experts = {
            "w_gate": jax.random.normal(keys[0], (E, d, ff), jnp.float32) * s,
            "w_up": jax.random.normal(keys[1], (E, d, ff), jnp.float32) * s,
            "w_down": jax.random.normal(keys[2], (E, ff, d), jnp.float32) * (ff ** -0.5),
        }
    else:
        experts = {
            "w_up": jax.random.normal(keys[0], (E, d, ff), jnp.float32) * s,
            "w_down": jax.random.normal(keys[1], (E, ff, d), jnp.float32) * (ff ** -0.5),
        }
    p = {"router": jax.random.normal(k_r, (d, E), jnp.float32) * s,
         "experts": experts}
    if cfg.moe_shared_expert:
        p["shared"] = init_mlp(k_s, cfg)
    return p


def _expert_ffn(experts, xe, mlp_type: str):
    """xe: (E, C, d) -> (E, C, d); batched over the expert dim (EP shards it)."""
    cd = xe.dtype
    if mlp_type == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, experts["w_gate"].astype(cd)))
        h = h * jnp.einsum("ecd,edf->ecf", xe, experts["w_up"].astype(cd))
    else:
        h = jnp.square(jax.nn.relu(
            jnp.einsum("ecd,edf->ecf", xe, experts["w_up"].astype(cd))))
    return jnp.einsum("ecf,efd->ecd", h, experts["w_down"].astype(cd))


# ----------------------------------------------------------------- routing --

class Routing(NamedTuple):
    """Per-token routing decision (all leading dims (B, S))."""
    gate: jax.Array        # f32 top-1 router probability
    expert_id: jax.Array   # int32 assigned expert
    slot: jax.Array        # int32 absolute position in the (row, expert) queue
    within: jax.Array      # int32 queue position within THIS call (slot - base)
    keep: jax.Array        # bool  slot < prefix capacity at the token's position
    new_counts: jax.Array  # (B, E) int32 occupancy after this call
    logits: jax.Array      # (B, S, E) f32 router logits (for aux losses)


def prefix_capacity(t, n_experts: int, capacity_factor: float) -> jax.Array:
    """Per-(row, expert) queue capacity after ``t + 1`` tokens:
    ``ceil((t+1)/E * capacity_factor)``.  Traceable in ``t``; decode and
    prefill call it with the same absolute positions, so the keep sets are
    bit-identical (the multiply happens in f32 in both)."""
    t1 = (jnp.asarray(t, jnp.int32) + 1).astype(jnp.float32)
    return jnp.ceil(t1 * np.float32(capacity_factor / n_experts)).astype(jnp.int32)


def dispatch_capacity(S: int, cfg: ArchConfig, pos0=0) -> int:
    """Static capacity of the dispatch buffer for an S-token call starting at
    absolute position ``pos0``.  Kept tokens satisfy ``within < S`` and
    ``within <= slot < C(pos0 + S - 1)``, so the min of the two bounds is a
    safe buffer size; when ``pos0`` is traced (stepwise decode) only the
    S bound is static.  Uses the same f32 arithmetic as
    :func:`prefix_capacity` so the bound can never be under the keep test."""
    if not isinstance(pos0, (int, np.integer)):
        return max(1, S)
    cap = int(np.ceil(np.float32(pos0 + S)
                      * np.float32(cfg.capacity_factor / cfg.n_experts)))
    return max(1, min(S, cap))


def route_tokens(router: jax.Array, x: jax.Array, cfg: ArchConfig, *,
                 counts: Optional[jax.Array] = None, pos0=0) -> Routing:
    """Top-1 routing with prefix-stable slot assignment.

    x: (B, S, d); ``counts``: (B, E) int32 occupancy carried from previous
    calls on the same rows (None = fresh sequence); ``pos0``: absolute
    position of x[:, 0] (int or traced scalar).  The decision for token
    (b, s) depends only on row b's tokens at positions <= pos0 + s.
    """
    B, S, _ = x.shape
    E = cfg.n_experts
    logits = x.astype(jnp.float32) @ router.astype(jnp.float32)   # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_id = jax.lax.top_k(probs, 1)                     # top-1 per pool spec
    gate, expert_id = gate[..., 0], expert_id[..., 0].astype(jnp.int32)

    onehot = jax.nn.one_hot(expert_id, E, dtype=jnp.int32)        # (B, S, E)
    if counts is None:
        counts = jnp.zeros((B, E), jnp.int32)
    # queue position = prior same-(row, expert) tokens, kept OR dropped
    within = ((jnp.cumsum(onehot, axis=1) - onehot) * onehot).sum(-1)
    base = (counts[:, None, :] * onehot).sum(-1)                  # (B, S)
    slot = base + within
    t_abs = jnp.asarray(pos0, jnp.int32) + jnp.arange(S, dtype=jnp.int32)
    keep = slot < prefix_capacity(t_abs, E, cfg.capacity_factor)[None, :]
    new_counts = counts + onehot.sum(axis=1)
    return Routing(gate, expert_id, slot, within, keep, new_counts, logits)


# ---------------------------------------------------------------- dispatch --

def _dispatch_gather(xt: jax.Array, flat_slot: jax.Array, E: int, C: int):
    """SU indirection dispatch: inverse index stream + gather.

    xt: (B, S, d); flat_slot: (B, S) in [0, E*C] (E*C = dropped).
    Returns (E, B, C, d) capacity tiles."""
    B, S, d = xt.shape
    inv = jnp.full((B, E * C + 1), S, jnp.int32)
    inv = inv.at[jnp.arange(B)[:, None], flat_slot].set(
        jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S)),
        mode="drop")[:, : E * C]
    xt_pad = jnp.concatenate([xt, jnp.zeros((B, 1, d), xt.dtype)], axis=1)
    xe = jnp.take_along_axis(xt_pad, inv[..., None], axis=1)      # (B, E*C, d)
    return xe.reshape(B, E, C, d).transpose(1, 0, 2, 3)


def _dispatch_bcsr(xt: jax.Array, flat_slot: jax.Array, E: int, C: int):
    """Dispatch-as-SpMM: per-row 0/1 dispatch matrices as one BatchedBCSR
    (shared index stream) through the sharded SpMM Pallas kernel.

    Eagerly the stream compacts to the union nonzero-block pattern; under
    tracing the pattern is the full grid (static shapes), which is the
    one-hot-einsum cost paid on the *kernel* path.  Returns (E, B, C, d),
    bit-identical to :func:`_dispatch_gather` (0/1 blocks, f32 accumulate).
    """
    from repro.core.formats import BatchedBCSR
    from repro.kernels import engine, tuning

    B, S, d = xt.shape
    tiles = tuning.moe_dispatch_tiles(d, xt.dtype)
    bm, bk = tiles["block"]
    M = E * C
    Mp = -(-M // bm) * bm
    Sp = -(-S // bk) * bk
    gm, gn = Mp // bm, Sp // bk

    # dense (B, Mp, Sp) dispatch matrix; dropped tokens write the slice-off row
    rows = jnp.where(flat_slot < M, flat_slot, Mp)
    disp = jnp.zeros((B, Mp + 1, Sp), xt.dtype)
    disp = disp.at[jnp.arange(B)[:, None], rows,
                   jnp.arange(S, dtype=jnp.int32)[None, :]].set(1)[:, :Mp]
    tiles4 = disp.reshape(B, gm, bm, gn, bk).transpose(0, 1, 3, 2, 4)

    if isinstance(tiles4, jax.core.Tracer):
        # static shapes under jit/scan: the stream is the full grid
        brows, bcols = np.nonzero(np.ones((gm, gn), bool))
    else:
        nz = np.array(jnp.any(tiles4 != 0, axis=(0, 3, 4)))
        nz[:, 0] = True  # kernel contract: every block-row appears
        brows, bcols = np.nonzero(nz)
    indptr = np.zeros(gm + 1, np.int32)
    np.cumsum(np.bincount(brows, minlength=gm), out=indptr[1:])
    # index stream stays host-side numpy: it is static (routing-independent
    # under tracing) and the engine inspects it with numpy before the call
    ab = BatchedBCSR(indptr=indptr,
                     block_rows=brows.astype(np.int32),
                     block_cols=bcols.astype(np.int32),
                     blocks=tiles4[:, brows, bcols],
                     shape=(B, Mp, Sp), block=(bm, bk))
    xt_p = jnp.pad(xt, ((0, 0), (0, Sp - S), (0, 0)))
    out = engine.shard_spmm_batched(ab, xt_p, bn=tiles["bn"],
                                    out_dtype=xt.dtype)      # (B, Mp, d)
    return out[:, :M].reshape(B, E, C, d).transpose(1, 0, 2, 3)


def _combine_gather(yt: jax.Array, flat_slot: jax.Array, gate: jax.Array,
                    keep: jax.Array, E: int, C: int):
    """Gather each token's expert output back by its own index; dropped
    tokens contribute zero.  yt: (B, E*C, d) -> (B, S, d)."""
    B = yt.shape[0]
    d = yt.shape[-1]
    yt_pad = jnp.concatenate([yt, jnp.zeros((B, 1, d), yt.dtype)], axis=1)
    back = jnp.take_along_axis(
        yt_pad, jnp.minimum(flat_slot, E * C)[..., None], axis=1)
    return back * (gate * keep).astype(back.dtype)[..., None]


# --------------------------------------------------------------- the layer --

def apply_moe(p, x, cfg: ArchConfig, *, counts: Optional[jax.Array] = None,
              pos=None, groups: Optional[int] = None,
              dispatch: Optional[str] = None):
    """x: (B, S, d) -> ((B, S, d), new_counts (B, E) int32).

    ``counts``/``pos`` thread the routing state for stepwise decode: pass the
    previous call's ``new_counts`` and the absolute position of x[:, 0] and a
    one-token step reproduces the prefill slot and drop decision bit-for-bit.
    Training/prefill callers pass neither (fresh sequence at position 0) and
    may discard the returned counts.

    ``dispatch`` selects the backend ("gather" | "bcsr"); default is
    ``context.MOE_DISPATCH`` then ``cfg.moe_dispatch``.

    Routing is per batch row, so under dp sharding of B the cumsum stays
    shard-local and the only cross-shard movement is the (E, B, C, d)
    dispatch -- the EP all-to-all.  ``groups`` (or ``context.MOE_GROUPS``)
    declares how many row groups the data axes expect; when it does not
    divide B the dispatch buffer cannot align with the data shards and the
    layer warns (raises under ``cfg.moe_strict_dispatch``) instead of
    silently falling back to an unaligned layout.
    """
    from repro.parallel import context as pctx
    from repro.parallel.sharding import constrain

    B, S, d = x.shape
    E = cfg.n_experts

    if pctx.MOE_IMPL == "shard_map" and pctx.MESH is not None:
        # train-only path: each (row, sequence-shard) chunk routes locally,
        # occupancy is NOT threaded across calls, and dispatch is always the
        # gather formulation.  A caller carrying routing state (decode) or
        # requesting the bcsr backend would silently lose prefix stability,
        # so that is an error in spirit -- surface it.
        backend = dispatch or pctx.MOE_DISPATCH or cfg.moe_dispatch
        if counts is not None or pos is not None or backend != "gather":
            msg = ("apply_moe: the shard_map impl is train-only -- it does "
                   "not thread routing occupancy (counts/pos) and only "
                   "supports moe_dispatch='gather'; decode and bcsr callers "
                   "must use the pjit impl.")
            if cfg.moe_strict_dispatch:
                raise ValueError(msg)
            warnings.warn(msg, RuntimeWarning, stacklevel=2)
        from repro.models.moe_shard_map import apply_moe_shard_map
        from repro.parallel.sharding import FSDP
        dp_axes = tuple(a for a in FSDP if a in pctx.MESH.axis_names)
        dp_axes = dp_axes if len(dp_axes) > 1 else dp_axes[0]
        out = apply_moe_shard_map(p, x, cfg, pctx.MESH, dp_axes=dp_axes,
                                  tp_axis="model")
        new_counts = counts if counts is not None else jnp.zeros((B, E), jnp.int32)
        return out, new_counts

    G = groups or pctx.MOE_GROUPS
    if G and B % G != 0:
        msg = (f"apply_moe: {G} dispatch group(s) requested but the batch "
               f"dim B={B} is not divisible; the (E, B, C, d) dispatch "
               "buffer cannot align with the data shards and falls back to "
               "an ungrouped layout (extra resharding under pjit).")
        if cfg.moe_strict_dispatch:
            raise ValueError(msg)
        warnings.warn(msg, RuntimeWarning, stacklevel=2)

    pos0 = 0 if pos is None else pos
    r = route_tokens(p["router"], x, cfg, counts=counts, pos0=pos0)
    C = dispatch_capacity(S, cfg, pos0=pos0)

    # --- SU dispatch: index stream (expert*C + within) per row -------------
    flat_slot = jnp.where(r.keep, r.expert_id * C + r.within, E * C)
    backend = dispatch or pctx.MOE_DISPATCH or cfg.moe_dispatch
    if backend == "bcsr":
        xe = _dispatch_bcsr(x, flat_slot, E, C)
    elif backend == "gather":
        xe = _dispatch_gather(x, flat_slot, E, C)
    else:
        raise ValueError(f"unknown moe_dispatch backend {backend!r}")
    if pctx.MOE_SPEC is not None:
        xe = constrain(xe, pctx.MOE_SPEC)                 # EP all-to-all

    ye = _expert_ffn(p["experts"], xe.reshape(E, B * C, d),
                     cfg.mlp_type).reshape(E, B, C, d)

    # --- SU combine: inverse all-to-all + gather back by the same stream ---
    # Constrain BACK to the dispatch (row-sharded) layout before the gather:
    # each token's result lives on exactly one expert shard, so the reshard is
    # an all-to-all; gathering straight from the EP layout instead makes GSPMD
    # emit a full-activation all-reduce per layer (measured: 5.4 GB -> 34 MB
    # per layer on llama4-scout train_4k).
    yt = ye.transpose(1, 0, 2, 3).reshape(B, E * C, d)
    if pctx.MOE_COMBINE_SPEC is not None:
        yt = constrain(yt, pctx.MOE_COMBINE_SPEC)
    out = _combine_gather(yt, flat_slot, r.gate, r.keep, E, C)

    if cfg.moe_shared_expert:
        out = out + apply_mlp(p["shared"], x.reshape(B * S, d),
                              cfg).reshape(B, S, d)
    return out, r.new_counts


def load_balance_loss(logits: jax.Array, expert_id: jax.Array, E: int):
    """Switch-style auxiliary loss (fraction-routed x mean-prob)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    frac = jnp.mean(jax.nn.one_hot(expert_id, E, dtype=jnp.float32), axis=0)
    mean_p = jnp.mean(probs, axis=0)
    return E * jnp.sum(frac * mean_p)
