"""Architecture configuration: one frozen dataclass drives the whole stack.

A model is a scanned stack of *superblocks* (the repeating unit). Each
superblock is a tuple of sub-layer kinds, so heterogeneous-but-periodic
stacks (Gemma-3's 5 local : 1 global, Llama-4's dense/MoE alternation,
Zamba-2's shared-attention insertions) scan homogeneously: params are stacked
along the repeat axis and `lax.scan` keeps the HLO one-superblock small.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

LayerKind = str  # attn | attn_local | attn_global | mamba | rwkv | <x>+moe ...


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    # stack structure
    block_unit: Tuple[LayerKind, ...]  # the repeating superblock
    n_repeats: int                     # stack = block_unit * n_repeats
    head_dim: Optional[int] = None     # default d_model // n_heads
    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    local_window: Optional[int] = None   # for attn_local layers
    rope_theta: float = 1e6
    # mlp
    mlp_type: str = "swiglu"             # swiglu | squared_relu
    # moe
    n_experts: int = 0
    top_k: int = 1
    capacity_factor: float = 1.25
    moe_shared_expert: bool = False      # Llama-4 style always-on shared expert
    # dispatch backend: "gather" (SU index-stream gather) or "bcsr" (dispatch
    # matrix as BatchedBCSR through the sharded SpMM Pallas kernel); may be
    # overridden per-trace via repro.parallel.context.MOE_DISPATCH
    moe_dispatch: str = "gather"
    # raise (instead of warn) when the requested dispatch grouping cannot
    # align with the batch dim -- see models.moe.apply_moe
    moe_strict_dispatch: bool = False
    # ssm (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    # zamba-style shared block: apply a single shared attention block after
    # every `shared_attn_every` scanned steps (0 = never)
    shared_attn_every: int = 0
    # extra leading layers of kind block_unit[0] outside the main scan (used
    # to hit exact layer counts, e.g. zamba2's 38 = 2 + 6*6)
    n_prologue: int = 0
    # frontend stubs: 'none' | 'vision' | 'audio' -- input_specs() then expects
    # precomputed patch/frame embeddings alongside (or instead of) tokens
    frontend: str = "none"
    frontend_tokens: int = 0             # prepended embedding positions
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # dtype policy name from repro.core.precision
    policy: str = "bf16"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows padded to a TP/FSDP-shardable multiple (256
        divides every production mesh axis product used here). Logits over
        padded ids are masked in the loss and sliced off in serving."""
        return -(-self.vocab_size // 256) * 256

    @property
    def n_layers(self) -> int:
        return len(self.block_unit) * self.n_repeats + self.n_prologue

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + stacked blocks)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        hd, Hq, Hkv = self.hd, self.n_heads, self.n_kv_heads
        n = V * d                      # embedding
        if not self.tie_embeddings:
            n += V * d                 # unembedding
        per_kind = {}
        attn = d * (Hq * hd) + 2 * d * (Hkv * hd) + (Hq * hd) * d
        if self.qkv_bias:
            attn += (Hq + 2 * Hkv) * hd
        mlp = (3 if self.mlp_type == "swiglu" else 2) * d * ff
        per_kind["attn"] = attn + mlp + 2 * d
        per_kind["attn_local"] = per_kind["attn_global"] = per_kind["attn"]
        moe_ffn = self.n_experts * (3 if self.mlp_type == "swiglu" else 2) * d * ff \
            + d * self.n_experts
        if self.moe_shared_expert:
            moe_ffn += (3 if self.mlp_type == "swiglu" else 2) * d * ff
        per_kind["attn+moe"] = attn + moe_ffn + 2 * d
        d_in = self.ssm_expand * d
        nh = d_in // self.ssm_head_dim
        mamba = d * (2 * d_in + 2 * self.ssm_state + nh) \
            + self.ssm_conv * (d_in + 2 * self.ssm_state) \
            + d_in * d + 2 * nh + d_in
        per_kind["mamba"] = mamba + d
        per_kind["rwkv"] = int(d * ff * 2 + d * d * 5 + 2 * d)  # see rwkv6.py
        for kind in self.block_unit:
            n += per_kind[kind] * self.n_repeats
        if self.n_prologue:
            n += per_kind[self.block_unit[0]] * self.n_prologue
        if self.shared_attn_every:
            n += per_kind["attn"]      # one shared block, reused
        return int(n)

    def active_param_count(self) -> int:
        """Active params per token (= total for dense; routed subset for MoE)."""
        if self.n_experts == 0:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        w = (3 if self.mlp_type == "swiglu" else 2) * d * ff
        inactive = (self.n_experts - self.top_k) * w
        n_moe_layers = sum(k == "attn+moe" for k in self.block_unit) * self.n_repeats
        return int(self.param_count() - inactive * n_moe_layers)
