"""Deterministic synthetic token pipeline with sharded batches + prefetch.

Production shape: an infinite, *step-addressable* stream -- batch(step) is a
pure function of (seed, step), so restart-after-failure resumes mid-epoch with
no data loss or duplication (the fault-tolerance contract runtime/trainer.py
relies on), and stragglers can't skew data order. A background thread
prefetches and device_puts the next batches (the DMA-core analogue at the
input layer).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig


class SyntheticLM:
    """Zipfian token stream with short-range structure (next-token learnable:
    t_{i+1} depends on t_i via a fixed permutation + noise), so quickstart
    training shows a real loss drop."""

    def __init__(self, cfg: ArchConfig, batch: int, seq_len: int,
                 seed: int = 0, noise: float = 0.1):
        self.cfg = cfg
        self.batch = batch
        s_front = cfg.frontend_tokens if cfg.frontend != "none" else 0
        self.seq_len = seq_len - s_front
        self.s_front = s_front
        self.seed = seed
        self.noise = noise
        rng = np.random.default_rng(seed)
        self.perm = rng.permutation(cfg.vocab_size)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        self.p = p / p.sum()

    def batch_at(self, step: int) -> dict:
        """Pure function of (seed, step): the resumability contract."""
        rng = np.random.default_rng((self.seed, step))
        B, S, V = self.batch, self.seq_len, self.cfg.vocab_size
        first = rng.choice(V, size=(B, 1), p=self.p)
        toks = np.empty((B, S), np.int32)
        toks[:, 0] = first[:, 0]
        flip = rng.random((B, S)) < self.noise
        rand = rng.choice(V, size=(B, S), p=self.p)
        for i in range(1, S):
            nxt = self.perm[toks[:, i - 1]]
            toks[:, i] = np.where(flip[:, i], rand[:, i], nxt)
        out = {"tokens": toks}
        if self.s_front:
            out["embeddings"] = rng.standard_normal(
                (B, self.s_front, self.cfg.d_model)).astype(np.float32) * 0.02
        return out

    def stream(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch + device_put (double-buffered input DMA)."""

    def __init__(self, it: Iterator[dict], depth: int = 2, shardings=None):
        self.it = it
        self.shardings = shardings
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.t = threading.Thread(target=self._worker, daemon=True)
        self.t.start()

    def _worker(self):
        try:
            for item in self.it:
                if self._stop.is_set():
                    return
                arrs = {k: jnp.asarray(v) for k, v in item.items()}
                if self.shardings:
                    arrs = {k: jax.device_put(v, self.shardings.get(k))
                            if self.shardings.get(k) else v
                            for k, v in arrs.items()}
                self.q.put(arrs)
        finally:
            self.q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
