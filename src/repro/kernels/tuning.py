"""Tile-size autotune table for the sparse / stencil Pallas kernels.

Occamy fixes its working-set geometry at silicon time (128 KiB TCDM per
cluster, 8-lane FPU SIMD); the TPU analogue is choosing Pallas block shapes
so one (A-block, B-tile, accumulator) working set fits VMEM while the MXU/VPU
tiles stay aligned to the native (8, 128) lane quantum.  This module replaces
the hardcoded ``bn=128`` / ``rt=ct=8`` / stencil-tile defaults scattered
through the ops layers with a single provenance-tracked table.

Provenance: entries were selected by sweeping interpret-mode correctness on
CPU and the roofline model in ``benchmarks/roofline.py`` for TPU shapes
(VMEM budget ~16 MiB/core, MXU 128x128, VPU 8x128).  They are *static*
heuristics, not on-device measurements -- re-measure when real TPU time is
available and override via :func:`register`.

Selection contract:
  * ``lookup("spmm", ...)``    -> {"bn": int}
  * ``lookup("spmspm", ...)``  -> {"rt": int, "ct": int}
  * ``lookup("stencil", ...)`` -> {"tile": Tuple[int, ...]}

On CPU (no TPU backend) every op falls back to the smallest aligned tile:
interpret mode emulates the grid serially, so large tiles only add padding
waste without any DMA-overlap benefit.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

# Native lane quanta: second-minor x minor tile of the VPU / MXU.
SUBLANE = 8
LANE = 128
# Per-core VMEM budget we allow one kernel working set to occupy (bytes).
VMEM_BUDGET = 8 * 2**20


@functools.lru_cache(maxsize=1)
def on_tpu() -> bool:
    """True when a real TPU backend is attached (tuning targets VMEM);
    otherwise the CPU/interpret fallback row is used."""
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # backend init can fail in exotic harnesses
        return False


def _dtype_bytes(dtype) -> int:
    return jnp.dtype(dtype).itemsize


# ---------------------------------------------------------------------------
# Table rows.  Key: (op, dtype-bucket, platform) -> params.  dtype-bucket is
# the accumulating-input width ("f32" for >=4-byte, "bf16" for 2-byte,
# "i8/fp8" for 1-byte); platform is "tpu" or "cpu".
# ---------------------------------------------------------------------------

def _bucket(dtype) -> str:
    b = _dtype_bytes(dtype)
    return "f32" if b >= 4 else ("bf16" if b == 2 else "fp8")


_TABLE: Dict[Tuple[str, str, str], Dict[str, Any]] = {
    # SpMM: bn is the dense-operand N-tile; nt is the output-residency width
    # (how many N-tiles of one output row stay VMEM-resident per walk of the
    # index/block stream -- the stream reread factor is N / (nt*bn)).  Wider
    # tiles amortize the per-step index-stream scalar read; narrower dtypes
    # double the lane capacity so the same VMEM footprint covers 2x/4x the
    # columns.  CPU/interpret rows pin nt=1: the grid is emulated serially,
    # so residency buys nothing and only adds padding waste.
    ("spmm", "f32", "tpu"): {"bn": 256, "nt": 4},
    ("spmm", "bf16", "tpu"): {"bn": 512, "nt": 4},
    ("spmm", "fp8", "tpu"): {"bn": 512, "nt": 4},
    ("spmm", "f32", "cpu"): {"bn": 128, "nt": 1},
    ("spmm", "bf16", "cpu"): {"bn": 128, "nt": 1},
    ("spmm", "fp8", "cpu"): {"bn": 128, "nt": 1},
    # SpMSpM: (rt, ct) is the dense accumulator tile; the all-pairs compare
    # issues rt*ct*Lb comparisons per step, so bigger tiles raise comparator
    # occupancy until the (rt, la) + (ct, lb) streams blow VMEM.  nt widens
    # the *output-column* residency: one kernel step computes (rt, nt*ct)
    # against an (nt*ct, lb) B-stream block, walking the A row stream once
    # per nt column tiles instead of once per tile.
    ("spmspm", "f32", "tpu"): {"rt": 16, "ct": 16, "nt": 2},
    ("spmspm", "bf16", "tpu"): {"rt": 16, "ct": 32, "nt": 2},
    ("spmspm", "fp8", "tpu"): {"rt": 16, "ct": 32, "nt": 2},
    ("spmspm", "f32", "cpu"): {"rt": 8, "ct": 8, "nt": 1},
    ("spmspm", "bf16", "cpu"): {"rt": 8, "ct": 8, "nt": 1},
    ("spmspm", "fp8", "cpu"): {"rt": 8, "ct": 8, "nt": 1},
    # MoE dispatch-as-SpMM (models.moe "bcsr" backend): ``block`` tiles the
    # 0/1 (slot, token) dispatch matrix -- small square blocks track the
    # one-nonzero-per-column structure; ``bn`` is the d_model N-tile of the
    # token operand streamed through the SpMM kernel.  ``min_bucket`` is the
    # floor of the power-of-two nnzb bucket the two-phase serving loop pads
    # routed index streams to (engine.stream_bucket): larger floors mean
    # fewer phase-2 recompiles at the cost of more zero-block stream work,
    # so the TPU row (compiles are expensive, streams are cheap) sits
    # higher than the CPU/interpret row.
    ("moe_dispatch", "f32", "tpu"): {"block": (8, 8), "bn": 256,
                                     "min_bucket": 32, "nt": 2},
    ("moe_dispatch", "bf16", "tpu"): {"block": (8, 8), "bn": 512,
                                      "min_bucket": 32, "nt": 2},
    ("moe_dispatch", "fp8", "tpu"): {"block": (8, 8), "bn": 512,
                                     "min_bucket": 32, "nt": 2},
    ("moe_dispatch", "f32", "cpu"): {"block": (8, 8), "bn": 128,
                                     "min_bucket": 8, "nt": 1},
    ("moe_dispatch", "bf16", "cpu"): {"block": (8, 8), "bn": 128,
                                      "min_bucket": 8, "nt": 1},
    ("moe_dispatch", "fp8", "cpu"): {"block": (8, 8), "bn": 128,
                                     "min_bucket": 8, "nt": 1},
    # WKV: the chunk length of the VMEM-resident-state recurrence kernel
    # (repro.kernels.wkv); longer chunks amortize the inter-chunk state
    # handoff, shorter ones bound the (chunk, chunk) intra-chunk attention
    # tile.  ops.wkv clamps to the (padded) sequence.
    ("wkv", "f32", "tpu"): {"chunk": 128},
    ("wkv", "bf16", "tpu"): {"chunk": 128},
    ("wkv", "fp8", "tpu"): {"chunk": 128},
    ("wkv", "f32", "cpu"): {"chunk": 128},
    ("wkv", "bf16", "cpu"): {"chunk": 128},
    ("wkv", "fp8", "cpu"): {"chunk": 128},
    # Flash attention: (bq, bk) query/key tile lengths.  Wider KV tiles cut
    # grid steps (fewer online-softmax rescales) until the double-buffered
    # (bk, D) K/V streams pressure VMEM; narrow dtypes afford wider tiles.
    # CPU rows keep the historical 128/128 (interpret mode, parity tests).
    ("flash", "f32", "tpu"): {"bq": 128, "bk": 256},
    ("flash", "bf16", "tpu"): {"bq": 128, "bk": 512},
    ("flash", "fp8", "tpu"): {"bq": 128, "bk": 512},
    ("flash", "f32", "cpu"): {"bq": 128, "bk": 128},
    ("flash", "bf16", "cpu"): {"bq": 128, "bk": 128},
    ("flash", "fp8", "cpu"): {"bq": 128, "bk": 128},
    # Block-sparse flash (BlockMask stream walk): narrower KV tiles than the
    # dense rows -- bk is also the mask's pattern resolution, so a narrower
    # tile walks fewer dead (q, k) pairs at the window/strided edges; sweeps
    # may register per-pattern overrides under "patterns": {name: {bq, bk}}.
    ("flash_sparse", "f32", "tpu"): {"bq": 128, "bk": 128},
    ("flash_sparse", "bf16", "tpu"): {"bq": 128, "bk": 256},
    ("flash_sparse", "fp8", "tpu"): {"bq": 128, "bk": 256},
    ("flash_sparse", "f32", "cpu"): {"bq": 128, "bk": 128},
    ("flash_sparse", "bf16", "cpu"): {"bq": 128, "bk": 128},
    ("flash_sparse", "fp8", "cpu"): {"bq": 128, "bk": 128},
    # Stencil: per-ndim halo tiles; minor dim pinned to the 128 lane width.
    ("stencil2d", "f32", "tpu"): {"tile": (256, 256)},
    ("stencil2d", "bf16", "tpu"): {"tile": (256, 512)},
    ("stencil2d", "fp8", "tpu"): {"tile": (256, 512)},
    ("stencil2d", "f32", "cpu"): {"tile": (64, 128)},
    ("stencil2d", "bf16", "cpu"): {"tile": (64, 128)},
    ("stencil2d", "fp8", "cpu"): {"tile": (64, 128)},
    ("stencil3d", "f32", "tpu"): {"tile": (8, 32, 256)},
    ("stencil3d", "bf16", "tpu"): {"tile": (8, 32, 512)},
    ("stencil3d", "fp8", "cpu"): {"tile": (8, 16, 128)},
    ("stencil3d", "f32", "cpu"): {"tile": (8, 16, 128)},
    ("stencil3d", "bf16", "cpu"): {"tile": (8, 16, 128)},
    ("stencil3d", "fp8", "tpu"): {"tile": (8, 32, 512)},
}


def register(op: str, dtype, params: Dict[str, Any], *, platform: str | None = None):
    """Override / extend a table row (e.g. from a measured on-device sweep)."""
    plat = platform or ("tpu" if on_tpu() else "cpu")
    _TABLE[(op, _bucket(dtype), plat)] = dict(params)


def _row(op: str, dtype) -> Dict[str, Any]:
    plat = "tpu" if on_tpu() else "cpu"
    key = (op, _bucket(dtype), plat)
    if key not in _TABLE:  # unknown bucket -> conservative f32/cpu row
        key = (op, "f32", "cpu")
    return dict(_TABLE[key])


# ---------------------------------------------------------------------------
# Per-op lookups (shape-aware clamping on top of the table row).
# ---------------------------------------------------------------------------

def _clamp_bn(bn: int, n: int, dtype, bk: int) -> int:
    """Clamp an SpMM-style N-tile: no wider than N rounded up to the lane
    width (a tile wider than the whole operand is pure padding), then halved
    while the (bk, bn) dense tile + (8, bn) f32 accumulator, double-buffered,
    would exceed the VMEM budget."""
    n_aligned = -(-max(n, 1) // LANE) * LANE
    bn = min(bn, max(LANE, n_aligned))
    while bn > LANE and 2 * (bk * bn * _dtype_bytes(dtype) + SUBLANE * bn * 4) > VMEM_BUDGET:
        bn //= 2
    return bn


def _clamp_nt(nt: int, bn: int, n: int, dtype, bk: int) -> int:
    """Clamp the SpMM output-residency width: the (bm-sublane, nt*bn) f32
    accumulator plus the double-buffered (bk, bn) dense stream must fit the
    VMEM budget, and a supertile wider than the whole (lane-aligned) operand
    is pure padding."""
    nt = max(1, int(nt))
    n_aligned = -(-max(n, 1) // LANE) * LANE
    while nt > 1 and (nt - 1) * bn >= n_aligned:
        nt //= 2
    while nt > 1 and (2 * bk * bn * _dtype_bytes(dtype)
                      + 2 * SUBLANE * nt * bn * 4) > VMEM_BUDGET:
        nt //= 2
    return nt


def spmm_bn(n: int, dtype=jnp.float32, *, bk: int = 8) -> int:
    """N-tile for the BCSR SpMM kernel (table row + shape/VMEM clamp)."""
    return _clamp_bn(int(_row("spmm", dtype)["bn"]), n, dtype, bk)


def spmm_tiles(n: int, dtype=jnp.float32, *, bk: int = 8) -> Dict[str, int]:
    """{"bn", "nt"} for the BCSR SpMM kernel: the N-tile plus the
    output-residency width (how many N-tiles stay VMEM-resident per walk of
    the index/block stream), both shape/VMEM clamped."""
    row = _row("spmm", dtype)
    bn = _clamp_bn(int(row["bn"]), n, dtype, bk)
    return {"bn": bn, "nt": _clamp_nt(int(row.get("nt", 1)), bn, n, dtype, bk)}


def spmspm_tiles(r: int, c: int, la: int, lb: int, dtype=jnp.float32
                 ) -> Tuple[int, int]:
    """(rt, ct) accumulator tile for the all-pairs intersection kernel."""
    row = _row("spmspm", dtype)
    rt, ct = int(row["rt"]), int(row["ct"])
    # Never tile wider than the (padded) problem.
    rt = min(rt, -(-max(r, 1) // SUBLANE) * SUBLANE)
    ct = min(ct, -(-max(c, 1) // SUBLANE) * SUBLANE)
    # Stream working set: (rt, la) + (ct, lb) keys+vals, int32+f32.
    while rt > SUBLANE and 8 * (rt * la + ct * lb) > VMEM_BUDGET:
        rt = max(SUBLANE, rt // 2)
        ct = max(SUBLANE, ct // 2)
    return rt, ct


def spmspm_nt(c: int, ct: int, lb: int, dtype=jnp.float32) -> int:
    """Output-column residency width for the intersection kernel: one step
    computes (rt, nt*ct) outputs from an (nt*ct, lb) B-stream block, so the
    A row stream is walked once per ``nt`` column tiles.  Clamped so the
    wider B block stays within the stream working-set budget."""
    nt = max(1, int(_row("spmspm", dtype).get("nt", 1)))
    c_aligned = -(-max(c, 1) // SUBLANE) * SUBLANE
    while nt > 1 and (nt - 1) * ct >= c_aligned:
        nt //= 2
    while nt > 1 and 8 * nt * ct * lb > VMEM_BUDGET:
        nt //= 2
    return nt


def moe_dispatch_tiles(d_model: int, dtype=jnp.float32) -> Dict[str, Any]:
    """{"block": (bm, bk), "bn": int, "min_bucket": int, "nt": int} for the
    MoE dispatch-as-SpMM path; ``bn`` (the d_model N-tile of the token
    operand) gets the same shape/VMEM clamp as :func:`spmm_bn` and ``nt``
    the residency clamp of :func:`spmm_tiles`; ``min_bucket`` feeds
    ``engine.stream_bucket`` when the routed stream is bucketed for the
    two-phase serving loop (rows registered without it fall back to 8)."""
    row = _row("moe_dispatch", dtype)
    bm, bk = row["block"]
    bn = _clamp_bn(int(row["bn"]), d_model, dtype, bk)
    return {"block": (int(bm), int(bk)), "bn": bn,
            "min_bucket": int(row.get("min_bucket", 8)),
            "nt": _clamp_nt(int(row.get("nt", 1)), bn, d_model, dtype, bk)}


def wkv_chunk(t: int, dtype=jnp.float32) -> int:
    """Chunk length for the WKV recurrence kernel, clamped to the sequence
    (the historical ``min(chunk, max(8, T))`` contract)."""
    return min(int(_row("wkv", dtype)["chunk"]), max(SUBLANE, int(t)))


def flash_tiles(sq: int, skv: int, d: int, dtype=jnp.float32
                ) -> Tuple[int, int]:
    """(bq, bk) tile lengths for the flash-attention kernel: no longer than
    the (sublane-aligned) sequences, and bk halves while the double-buffered
    K+V streams plus the f32 accumulator/softmax state would exceed the
    VMEM budget (ops applies its divisibility-aware re-clamp on top)."""
    row = _row("flash", dtype)
    bq, bk = int(row["bq"]), int(row["bk"])
    bq = min(bq, -(-max(sq, 1) // SUBLANE) * SUBLANE)
    bk = min(bk, -(-max(skv, 1) // SUBLANE) * SUBLANE)
    eb = _dtype_bytes(dtype)
    while bk > LANE and (4 * bk * d * eb + bq * d * 4
                         + 2 * bq * d * eb) > VMEM_BUDGET:
        bk //= 2
    return bq, bk


def flash_sparse_tiles(sq: int, skv: int, d: int, dtype=jnp.float32, *,
                       pattern: str | None = None) -> Tuple[int, int]:
    """(bq, bk) for the block-sparse flash kernel.  The table row may carry
    per-pattern overrides (``"patterns": {"window": {"bq", "bk"}, ...}``,
    registered by ``benchmarks/sweep_tiles.py``); shape/VMEM clamping matches
    :func:`flash_tiles`."""
    row = _row("flash_sparse", dtype)
    if not row:  # missing fallback row -> share the dense flash defaults
        row = _row("flash", dtype)
    params = dict(row)
    if pattern is not None:
        params.update(row.get("patterns", {}).get(pattern, {}))
    bq, bk = int(params["bq"]), int(params["bk"])
    bq = min(bq, -(-max(sq, 1) // SUBLANE) * SUBLANE)
    bk = min(bk, -(-max(skv, 1) // SUBLANE) * SUBLANE)
    eb = _dtype_bytes(dtype)
    while bk > LANE and (4 * bk * d * eb + bq * d * 4
                         + 2 * bq * d * eb) > VMEM_BUDGET:
        bk //= 2
    return bq, bk


def stencil_tile(interior: Tuple[int, ...], dtype=jnp.float32) -> Tuple[int, ...]:
    """Halo-tile for the 2-D/3-D stencil kernels (minor dim lane-aligned)."""
    ndim = len(interior)
    tile = tuple(_row(f"stencil{ndim}d", dtype)["tile"])
    # Clamp each dim to the interior rounded up to its alignment quantum
    # (8 for majors, 128 for the minor) -- ops.apply re-clamps identically,
    # so the table only ever *suggests*.
    out = []
    for i, (t, n) in enumerate(zip(tile, interior)):
        q = LANE if i == ndim - 1 else SUBLANE
        out.append(min(t, -(-max(n, 1) // q) * q))
    return tuple(out)


def lookup(op: str, *, dtype=jnp.float32, **shape) -> Dict[str, Any]:
    """Generic front door used by benchmarks / diagnostics."""
    if op == "spmm":
        return spmm_tiles(shape.get("n", LANE), dtype,
                          bk=shape.get("bk", SUBLANE))
    if op == "spmspm":
        rt, ct = spmspm_tiles(shape.get("r", SUBLANE), shape.get("c", SUBLANE),
                              shape.get("la", 1), shape.get("lb", 1), dtype)
        return {"rt": rt, "ct": ct,
                "nt": spmspm_nt(shape.get("c", SUBLANE), ct,
                                shape.get("lb", 1), dtype)}
    if op == "moe_dispatch":
        return moe_dispatch_tiles(shape.get("d_model", LANE), dtype)
    if op == "wkv":
        return {"chunk": wkv_chunk(shape.get("t", LANE), dtype)}
    if op == "flash":
        bq, bk = flash_tiles(shape.get("sq", LANE), shape.get("skv", LANE),
                             shape.get("d", LANE), dtype)
        return {"bq": bq, "bk": bk}
    if op == "flash_sparse":
        bq, bk = flash_sparse_tiles(shape.get("sq", LANE),
                                    shape.get("skv", LANE),
                                    shape.get("d", LANE), dtype,
                                    pattern=shape.get("pattern"))
        return {"bq": bq, "bk": bk}
    if op == "stencil":
        return {"tile": stencil_tile(shape["interior"], dtype)}
    raise KeyError(f"unknown op {op!r}")
