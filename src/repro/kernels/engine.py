"""Sharded + batched sparse execution engine: the "48 clusters" layer.

Occamy scales a single compute cluster to 48 by replicating it behind two
HBM stacks and a D2D link; each cluster sees the *same* index stream but a
different slice of the dense data.  The JAX translation is ``shard_map``
over a device mesh:

  * **SpMM**   -- the BCSR index stream + blocks are replicated to every
    device (the paper's per-cluster index-stream copy), the dense operand is
    partitioned along its N columns (each chiplet's HBM holds its slice),
    and every device runs the *same* Pallas kernel on its slice.  The
    result is N-partitioned; materializing it is the all-gather.
  * **Batched SpMM** -- a :class:`~repro.core.formats.BatchedBCSR` batch is
    partitioned along the batch dim (whole problems per device, MoE-style),
    with the shared index stream again replicated.
  * **SpMSpM** -- A's row streams are replicated, B's column streams are
    partitioned, so each device owns a column stripe of the output.

Because each device executes the identical kernel on the identical operand
values for its output tiles, sharded fp32 results are **bit-for-bit** equal
to the single-device kernel (verified in tests/test_sparse_engine.py).

Mesh resolution: explicit ``mesh=`` arg > ``repro.parallel.context.MESH``
(set by the step builders) > an automatic 1-D ("data",) mesh over all local
devices.  On CPU the kernels run in interpret mode automatically.
"""
from __future__ import annotations

import collections
import functools
import os
import warnings
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.formats import BCSR, INVALID_KEY, BatchedBCSR
from repro.parallel.sharding import compat_shard_map
from repro.kernels import tuning
from repro.kernels.spmm import ops as spmm_ops
from repro.kernels.spmm.kernel import spmm_bcsr
from repro.kernels.spmspm.kernel import spmspm_ell


_PROBE_MISSING = object()


def backend_initialized() -> Optional[bool]:
    """Best-effort, side-effect-free probe: has a jax backend initialized?

    Returns True/False when one of the known (private) probe points exists,
    or ``None`` when a jax upgrade has moved them all -- callers must treat
    ``None`` as "unknown" and fall back to public APIs (which may themselves
    initialize the backend), never crash.  There is deliberately no public
    side-effect-free probe in jax, hence the version-tolerant ladder."""
    import importlib
    for mod_name, attr in (("jax._src.xla_bridge", "_backends"),
                           ("jax.lib.xla_bridge", "_backends")):
        try:
            mod = importlib.import_module(mod_name)
        except Exception:
            continue
        probe = getattr(mod, attr, _PROBE_MISSING)
        if probe is _PROBE_MISSING:
            continue
        try:
            return bool(probe)
        except Exception:
            return None
    return None


def ensure_virtual_devices(n: int = 4, *, strict: bool = False) -> None:
    """Force >= ``n`` virtual CPU devices (tests / CLI demos on one host).

    Must run before the first jax backend touch; a no-op if XLA_FLAGS
    already forces a count or a real multi-device backend exists.  The env
    flag cannot take effect once the backend has initialized, so if that
    already happened with fewer than ``n`` devices this *warns* (or raises
    under ``strict=True``) instead of silently leaving sharded tests running
    on a single device."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip())
    initialized = backend_initialized()
    if initialized is None:
        # Probe points moved (jax upgrade): fall back to the public device
        # count.  This *does* initialize the backend, but the flag above is
        # already exported, so a fresh init honors it and the count check
        # below stays accurate; a short count can only mean the backend
        # predates this call.
        initialized = True
    if initialized and jax.local_device_count() < n:
        msg = (f"ensure_virtual_devices({n}): the JAX backend already "
               f"initialized with {jax.local_device_count()} device(s); the "
               "XLA_FLAGS override cannot take effect in this process. "
               "Sharded code will run on fewer devices than requested -- "
               "call ensure_virtual_devices() before any jax API that "
               "touches the backend (or set XLA_FLAGS in the environment).")
        if strict:
            raise RuntimeError(msg)
        warnings.warn(msg, RuntimeWarning, stacklevel=2)


def _interpret_default(interpret: Optional[bool]) -> bool:
    return (not tuning.on_tpu()) if interpret is None else interpret


_MESH_INTERN: dict = {}


def _intern_mesh(mesh: Mesh) -> Mesh:
    """Canonicalize equal meshes to one object so the lru-cached sharded
    functions key on *mesh value semantics* -- (device assignment, axis
    names) -- not on whatever ``Mesh.__hash__`` does on the installed jax.
    Step builders recreate meshes freely; the caches must not depend on a
    version-specific Mesh identity/equality contract to stay hot.  The
    intern table is bounded by the number of distinct topologies a process
    ever builds (a handful)."""
    key = (tuple(mesh.devices.flat), mesh.devices.shape, mesh.axis_names)
    return _MESH_INTERN.setdefault(key, mesh)


def auto_mesh(mesh: Optional[Mesh] = None) -> Tuple[Mesh, str]:
    """Resolve (mesh, shard-axis): arg > parallel-context mesh > all devices.

    The resolved mesh is interned (see :func:`_intern_mesh`), so two equal
    meshes resolve to the same object and downstream lru caches hit."""
    if mesh is None:
        from repro.parallel import context as pctx
        mesh = pctx.MESH
    if mesh is None:
        devs = jax.devices()
        mesh = jax.make_mesh((len(devs),), ("data",))
    mesh = _intern_mesh(mesh)
    axis = "data" if "data" in mesh.axis_names else mesh.axis_names[0]
    return mesh, axis


def stream_bucket(nnzb: int, *, minimum: int = 8) -> int:
    """Snap a routed nonzero-block count to its power-of-two bucket.

    Two-phase serving (route on host, execute under jit) pads the index
    stream to ``stream_bucket(nnzb)`` entries before handing it to a
    compiled step, so the compile cache is keyed on the bucket, not the raw
    data-dependent count: recompiles are bounded by ``log2(grid)`` buckets
    while the stream stays within ``max(2 * nnzb, minimum)`` -- the floor
    dominates on tiny (decode-step) streams, the 2x law everywhere else."""
    n = max(int(nnzb), int(minimum), 1)
    return 1 << (n - 1).bit_length()


def batch_bucket(n: int, *, minimum: int = 1, cap: Optional[int] = None) -> int:
    """The stream-bucket law applied to the *batch* dimension.

    Continuous-batching serving (``launch.serve.ServeScheduler``) runs each
    decode step at ``batch_bucket(active_rows)`` so batch-composition
    changes (join/evict between steps) hit a bounded set of compiled step
    shapes -- one per power-of-two bucket -- instead of one per occupancy
    count.  ``cap`` clamps to the allocated slot count (itself bucketed at
    allocation time, so the clamp never produces a non-bucket shape)."""
    b = stream_bucket(n, minimum=minimum)
    return min(b, cap) if cap is not None else b


class StreamPipeline:
    """Depth-bounded in-flight buffer for routed dispatch streams: the
    serving-loop analogue of the SpMM kernel's double-buffered K-tiles.

    The pipelined two-phase serving loop routes layer L+1 on host while
    layer L's compiled execute phase is still in flight on the device.
    This buffer is the explicit two-slot structure bounding that overlap:
    :meth:`push` enqueues a freshly *dispatched* (not awaited) execute
    result together with the routed plan/stream that produced it -- keeping
    the stream's device buffers referenced while the kernel consumes them --
    then blocks the oldest entry out whenever more than ``depth`` are in
    flight.

    * ``depth=0`` -- every push drains immediately: fully serial, the
      pre-pipelining ``block_until_ready``-per-layer behavior bit-for-bit.
    * ``depth=1`` -- one execute rides in flight behind the host's route
      work for the next layer (double buffering); pushing the next execute
      first waits out the previous one.

    :meth:`busy` probes (``jax.Array.is_ready``, failing closed to "in
    flight" if a jax version drops the probe) whether an in-flight execute
    is still running on the device -- what the serving loop samples at
    route entry to attribute the route fetch wait as *hidden* behind
    device compute rather than serial with it."""

    def __init__(self, depth: int = 0):
        if depth not in (0, 1):
            raise ValueError(
                f"StreamPipeline depth must be 0 (serial) or 1 (double "
                f"buffered), got {depth!r}")
        self.depth = depth
        self.pushes = 0
        self._inflight: collections.deque = collections.deque()

    def __len__(self) -> int:
        return len(self._inflight)

    def push(self, tag, handle) -> None:
        """Enqueue a dispatched result; block the oldest out beyond depth.

        If waiting an entry out raises (a deferred device error surfacing at
        the sync point), every remaining in-flight entry is released via
        :meth:`abort` before the exception propagates -- the pipeline never
        wedges with a leaked slot."""
        self._inflight.append((tag, handle))
        self.pushes += 1
        try:
            while len(self._inflight) > self.depth:
                _, h = self._inflight.popleft()
                jax.block_until_ready(h)
        except BaseException:
            self.abort()
            raise

    def busy(self) -> bool:
        """Is any in-flight entry still executing on the device?"""
        for _, h in self._inflight:
            for leaf in jax.tree.leaves(h):
                is_ready = getattr(leaf, "is_ready", None)
                if is_ready is None or not is_ready():
                    return True
        return False

    def drain(self) -> None:
        """Block every in-flight entry out (phase boundary / loop reset).

        Exception-safe like :meth:`push`: a failing wait aborts the rest of
        the queue before re-raising, so the pipeline is empty either way."""
        try:
            while self._inflight:
                _, h = self._inflight.popleft()
                jax.block_until_ready(h)
        except BaseException:
            self.abort()
            raise

    def abort(self) -> None:
        """Release every in-flight entry without raising: best-effort wait
        (swallowing deferred device errors -- they already surfaced or are
        being handled by the caller) and unconditionally empty the queue, so
        the next ``decode_step`` starts from a clean pipeline."""
        while self._inflight:
            _, h = self._inflight.popleft()
            try:
                jax.block_until_ready(h)
            except Exception:
                pass


def _pad_dim(x: jax.Array, dim: int, multiple: int, value=0) -> jax.Array:
    pad = (-x.shape[dim]) % multiple
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[dim] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------
# SpMM: N-column partitioning (replicated index stream, sliced dense HBM).
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _sharded_spmm_fn(mesh: Mesh, axis: str, gm: int, bn: int, nt: int,
                     out_dtype: str, interpret: bool, quant: bool = False):
    kern = functools.partial(spmm_bcsr, n_block_rows=gm, bn=bn, nt=nt,
                             out_dtype=jnp.dtype(out_dtype), interpret=interpret)
    if quant:
        # BlockQuant stream: per-block scales replicated alongside the index
        # stream (every device dequantizes the same narrow blocks).
        return jax.jit(compat_shard_map(
            lambda rows, cols, blocks, scales, dense: kern(
                rows, cols, blocks, dense, scales=scales),
            mesh=mesh,
            in_specs=(P(), P(), P(), P(), P(None, axis)),
            out_specs=P(None, axis),
            check=False,
        ))
    return jax.jit(compat_shard_map(
        lambda rows, cols, blocks, dense: kern(rows, cols, blocks, dense),
        mesh=mesh,
        in_specs=(P(), P(), P(), P(None, axis)),
        out_specs=P(None, axis),
        check=False,  # pallas_call has no replication/vma rule
    ))


def shard_spmm(a: BCSR, dense: jax.Array, *, mesh: Optional[Mesh] = None,
               bn: Optional[int] = None, nt: Optional[int] = None,
               out_dtype=jnp.float32,
               interpret: Optional[bool] = None) -> jax.Array:
    """C = A @ dense with dense's N-tiles partitioned across the mesh.

    Handles uneven splits: N is zero-padded up to ``n_dev * nt * bn``
    granularity and the pad is stripped after the gather, so any N works on
    any mesh.  ``nt`` is the per-device output-residency width (each device
    re-walks the replicated index stream ``ceil(N_local / (nt*bn))``
    times)."""
    mesh, axis = auto_mesh(mesh)
    n_dev = mesh.shape[axis]
    interpret = _interpret_default(interpret)
    a = spmm_ops.pad_empty_rows(a)
    K, N = dense.shape
    assert K == a.shape[1], (a.shape, dense.shape)
    n_local = max(1, N // n_dev)
    tile_dtype = a.blocks.dtype if a.scales is not None else dense.dtype
    bn = spmm_ops._resolve_bn(bn, n_local, tile_dtype, a.block[1])
    nt = spmm_ops._resolve_nt(nt, bn, n_local, tile_dtype, a.block[1])
    dense = _pad_dim(dense, 1, n_dev * nt * bn)
    gm, _ = a.grid_shape
    fn = _sharded_spmm_fn(mesh, axis, gm, bn, nt, jnp.dtype(out_dtype).name,
                          interpret, quant=a.scales is not None)
    if a.scales is not None:
        out = fn(a.block_rows, a.block_cols, a.blocks, a.scales, dense)
    else:
        out = fn(a.block_rows, a.block_cols, a.blocks, dense)
    return out[:, :N]


# ---------------------------------------------------------------------------
# Batched SpMM: batch partitioning (whole problems per device, MoE-style).
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _sharded_spmm_batched_fn(mesh: Mesh, axis: str, gm: int, bn: int, nt: int,
                             out_dtype: str, interpret: bool,
                             quant: bool = False):
    kern = functools.partial(spmm_bcsr, n_block_rows=gm, bn=bn, nt=nt,
                             out_dtype=jnp.dtype(out_dtype), interpret=interpret)

    if quant:
        def local_q(rows, cols, blocks, scales, dense):
            # per-batch scales ride the batch partition with their blocks
            return jax.vmap(lambda bl, s, d: kern(rows, cols, bl, d, scales=s)
                            )(blocks, scales, dense)

        return jax.jit(compat_shard_map(
            local_q, mesh=mesh,
            in_specs=(P(), P(), P(axis), P(axis), P(axis)),
            out_specs=P(axis),
            check=False,
        ))

    def local(rows, cols, blocks, dense):
        # vmap over this device's slice of the batch; index stream shared.
        return jax.vmap(lambda bl, d: kern(rows, cols, bl, d))(blocks, dense)

    return jax.jit(compat_shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(), P(axis), P(axis)),
        out_specs=P(axis),
        check=False,
    ))


def shard_spmm_batched_stream(a: BatchedBCSR, dense: jax.Array, *,
                              mesh: Optional[Mesh] = None,
                              bn: Optional[int] = None,
                              nt: Optional[int] = None,
                              out_dtype=jnp.float32,
                              interpret: Optional[bool] = None) -> jax.Array:
    """Trace-safe batched SpMM on a *pre-normalized* stream.

    Contract: every block-row of ``a`` already appears in the stream (e.g.
    the caller ran :func:`repro.kernels.spmm.ops.pad_empty_rows` or built
    the stream with row coverage, as ``BatchedBCSR.with_capacity`` padding
    preserves).  Unlike :func:`shard_spmm_batched` this never inspects the
    index stream host-side, so it can be called *under jit* with the stream
    arrays as traced arguments -- the compile cache then keys on the stream
    *shape* (a bucketed capacity), never on the concrete index values.  This
    is the phase-2 entry point of the two-phase route-then-compile serving
    loop (see models.moe.execute_moe)."""
    mesh, axis = auto_mesh(mesh)
    n_dev = mesh.shape[axis]
    interpret = _interpret_default(interpret)
    B = a.batch
    if dense.ndim == 2:
        dense = jnp.broadcast_to(dense, (B,) + dense.shape)
    assert dense.shape[0] == B and dense.shape[1] == a.shape[2], (
        a.shape, dense.shape)
    N = dense.shape[2]
    tile_dtype = a.blocks.dtype if a.scales is not None else dense.dtype
    bn = spmm_ops._resolve_bn(bn, N, tile_dtype, a.block[1])
    nt = spmm_ops._resolve_nt(nt, bn, N, tile_dtype, a.block[1])
    dense = _pad_dim(_pad_dim(dense, 2, nt * bn), 0, n_dev)
    blocks = _pad_dim(a.blocks, 0, n_dev)
    gm, _ = a.grid_shape
    fn = _sharded_spmm_batched_fn(mesh, axis, gm, bn, nt,
                                  jnp.dtype(out_dtype).name, interpret,
                                  quant=a.scales is not None)
    if a.scales is not None:
        scales = _pad_dim(a.scales, 0, n_dev, value=1.0)
        out = fn(jnp.asarray(a.block_rows), jnp.asarray(a.block_cols), blocks,
                 scales, dense)
    else:
        out = fn(jnp.asarray(a.block_rows), jnp.asarray(a.block_cols), blocks,
                 dense)
    return out[:B, :, :N]


def shard_spmm_batched(a: BatchedBCSR, dense: jax.Array, *,
                       mesh: Optional[Mesh] = None, bn: Optional[int] = None,
                       nt: Optional[int] = None, out_dtype=jnp.float32,
                       interpret: Optional[bool] = None) -> jax.Array:
    """C[b] = A[b] @ dense[b], batch dim partitioned across the mesh.

    ``dense``: (B, K, N) or (K, N) broadcast. The batch is zero-padded up to
    a device multiple (zero blocks x zero dense = zero work rows) and the
    pad stripped after.  Host-side entry: the index stream is inspected with
    numpy (empty-row padding), so call it eagerly; under jit use
    :func:`shard_spmm_batched_stream` on a pre-normalized stream."""
    a = spmm_ops.pad_empty_rows(a)
    return shard_spmm_batched_stream(a, dense, mesh=mesh, bn=bn, nt=nt,
                                     out_dtype=out_dtype, interpret=interpret)


def shard_spmm_batched_bucketed(a: BatchedBCSR, dense: jax.Array, *,
                                mesh: Optional[Mesh] = None,
                                bn: Optional[int] = None,
                                nt: Optional[int] = None,
                                min_bucket: int = 8,
                                out_dtype=jnp.float32,
                                interpret: Optional[bool] = None
                                ) -> jax.Array:
    """Like :func:`shard_spmm_batched`, but the stream is padded up to its
    power-of-two bucket (:func:`stream_bucket`) before the call, so a
    sequence of calls with *varying* nnzb hits a bounded set of compiled
    programs (one per bucket) instead of one per count."""
    a = spmm_ops.pad_empty_rows(a)
    a = a.with_capacity(stream_bucket(a.nnzb, minimum=min_bucket))
    return shard_spmm_batched_stream(a, dense, mesh=mesh, bn=bn, nt=nt,
                                     out_dtype=out_dtype, interpret=interpret)


# ---------------------------------------------------------------------------
# SpMSpM: B-column-stream partitioning (each device owns an output stripe).
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _sharded_spmspm_fn(mesh: Mesh, axis: str, rt: int, ct: int, nt: int,
                       out_dtype: str, interpret: bool, quant: bool = False):
    kern = functools.partial(spmspm_ell, rt=rt, ct=ct, nt=nt,
                             out_dtype=jnp.dtype(out_dtype), interpret=interpret)
    if quant:
        # Per-row scales are replicated with A's row streams.
        return jax.jit(compat_shard_map(
            lambda ak, av, asc, bk, bv: kern(ak, av, bk, bv, a_scales=asc),
            mesh=mesh,
            in_specs=(P(), P(), P(), P(axis, None), P(axis, None)),
            out_specs=P(None, axis),
            check=False,
        ))
    return jax.jit(compat_shard_map(
        lambda ak, av, bk, bv: kern(ak, av, bk, bv),
        mesh=mesh,
        in_specs=(P(), P(), P(axis, None), P(axis, None)),
        out_specs=P(None, axis),
        check=False,
    ))


def shard_spmspm(a_keys, a_vals, b_keys, b_vals, *,
                 mesh: Optional[Mesh] = None, rt: Optional[int] = None,
                 ct: Optional[int] = None, nt: Optional[int] = None,
                 out_dtype=jnp.float32,
                 interpret: Optional[bool] = None,
                 a_scales: Optional[jax.Array] = None) -> jax.Array:
    """Sharded sorted-stream intersection: A's row streams replicated, B's
    column streams partitioned; device d computes output columns of its B
    stripe.  R is padded to ``rt`` and C to ``n_dev * nt * ct`` (INVALID
    keys, zero values -- they can never match) and both pads are stripped.
    ``nt`` is the per-device output-column residency width.  ``a_scales``
    ((R,) f32) carries BlockQuant per-row scales for narrow ``a_vals``."""
    mesh, axis = auto_mesh(mesh)
    n_dev = mesh.shape[axis]
    interpret = _interpret_default(interpret)
    ak, av = jnp.asarray(a_keys), jnp.asarray(a_vals)
    bk, bv = jnp.asarray(b_keys), jnp.asarray(b_vals)
    R, C = ak.shape[0], bk.shape[0]
    if rt is None or ct is None:
        trt, tct = tuning.spmspm_tiles(R, max(1, C // n_dev), ak.shape[1],
                                       bk.shape[1], av.dtype)
        rt, ct = rt or trt, ct or tct
    if nt is None:
        nt = tuning.spmspm_nt(max(1, C // n_dev), ct, bk.shape[1], av.dtype)
    elif int(nt) < 1:
        raise ValueError(f"nt={nt} must be >= 1")
    nt = int(nt)
    ak = _pad_dim(ak, 0, rt, value=INVALID_KEY)
    av = _pad_dim(av, 0, rt)
    bk = _pad_dim(bk, 0, n_dev * nt * ct, value=INVALID_KEY)
    bv = _pad_dim(bv, 0, n_dev * nt * ct)
    fn = _sharded_spmspm_fn(mesh, axis, rt, ct, nt, jnp.dtype(out_dtype).name,
                            interpret, quant=a_scales is not None)
    if a_scales is not None:
        asc = jnp.asarray(a_scales, jnp.float32).reshape(R, 1)
        asc = _pad_dim(asc, 0, rt, value=1.0)
        return fn(ak, av, asc, bk, bv)[:R, :C]
    return fn(ak, av, bk, bv)[:R, :C]


# ---------------------------------------------------------------------------
# Block-sparse attention: query-axis sharding of the BlockMask stream walk.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _sharded_attention_sparse_fn(mesh: Mesh, axis: str, s_loc: int,
                                 skv: int, window: Optional[int], bq: int,
                                 bk: int, scale: Optional[float],
                                 interpret: bool):
    from repro.kernels.flash_attention.kernel import flash_attention_sparse

    def local(q, k, v, rows, cols, kinds):
        # Per-shard absolute query offset keeps causal/window refinements
        # exact -- the sharded-flash q_offset recipe, stream-walk edition.
        off = jax.lax.axis_index(axis) * s_loc
        return flash_attention_sparse(q, k, v, rows[0], cols[0], kinds[0],
                                      skv=skv, window=window, scale=scale,
                                      bq=bq, bk=bk, q_offset=off,
                                      interpret=interpret)

    return jax.jit(compat_shard_map(
        local, mesh=mesh,
        in_specs=(P(None, None, axis, None), P(), P(),
                  P(axis), P(axis), P(axis)),
        out_specs=P(None, None, axis, None),
        check=False,
    ))


def shard_attention_sparse(q: jax.Array, k: jax.Array, v: jax.Array, mask, *,
                           mesh: Optional[Mesh] = None,
                           scale: Optional[float] = None,
                           interpret: Optional[bool] = None) -> jax.Array:
    """Block-sparse flash attention with the query axis sharded.

    The ``shard_spmm_batched_stream`` recipe applied to attention: the
    BlockMask is split into per-shard row sub-masks (``mask.shard_rows``),
    each lowered to the common power-of-two bucket capacity so every device
    runs the same compiled stream shape; K/V are replicated, queries are
    partitioned, and a per-shard ``q_offset`` (from ``axis_index``) keeps
    the absolute-position causal/window refinements exact.

    ``mask`` must cover (Sq, Skv) with Sq % (n_dev * bq) == 0.
    """
    mesh, axis = auto_mesh(mesh)
    n_dev = mesh.shape[axis]
    interpret = _interpret_default(interpret)
    B, Hq, Sq, D = q.shape
    Skv = k.shape[2]
    assert mask.sq == Sq and mask.skv == Skv, (mask.sq, mask.skv, Sq, Skv)
    assert mask.q_offset == 0, "shard_attention_sparse wants the full mask"
    assert Sq % (n_dev * mask.bq) == 0, (Sq, n_dev, mask.bq)
    s_loc = Sq // n_dev
    kp = (-Skv) % mask.bk
    if kp:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, kp), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, kp), (0, 0)))
    subs = mask.shard_rows(n_dev)
    # Common bucketed capacity: same compiled shape on every device.
    cap = stream_bucket(max(s.lower(bucket=False).capacity for s in subs))
    streams = [s.lower(capacity=cap) for s in subs]
    rows = jnp.asarray(np.stack([s.rows for s in streams]))
    cols = jnp.asarray(np.stack([s.cols for s in streams]))
    kinds = jnp.asarray(np.stack([s.kinds for s in streams]))
    fn = _sharded_attention_sparse_fn(mesh, axis, s_loc, Skv, mask.window,
                                      mask.bq, mask.bk, scale, interpret)
    return fn(q, k, v, rows, cols, kinds)
