"""Sharded + batched sparse execution engine: the "48 clusters" layer.

Occamy scales a single compute cluster to 48 by replicating it behind two
HBM stacks and a D2D link; each cluster sees the *same* index stream but a
different slice of the dense data.  The JAX translation is ``shard_map``
over a device mesh:

  * **SpMM**   -- the BCSR index stream + blocks are replicated to every
    device (the paper's per-cluster index-stream copy), the dense operand is
    partitioned along its N columns (each chiplet's HBM holds its slice),
    and every device runs the *same* Pallas kernel on its slice.  The
    result is N-partitioned; materializing it is the all-gather.
  * **Batched SpMM** -- a :class:`~repro.core.formats.BatchedBCSR` batch is
    partitioned along the batch dim (whole problems per device, MoE-style),
    with the shared index stream again replicated.
  * **SpMSpM** -- A's row streams are replicated, B's column streams are
    partitioned, so each device owns a column stripe of the output.

Because each device executes the identical kernel on the identical operand
values for its output tiles, sharded fp32 results are **bit-for-bit** equal
to the single-device kernel (verified in tests/test_sparse_engine.py).

Mesh resolution: explicit ``mesh=`` arg > ``repro.parallel.context.MESH``
(set by the step builders) > an automatic 1-D ("data",) mesh over all local
devices.  On CPU the kernels run in interpret mode automatically.
"""
from __future__ import annotations

import functools
import os
import warnings
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.formats import BCSR, INVALID_KEY, BatchedBCSR
from repro.parallel.sharding import compat_shard_map
from repro.kernels import tuning
from repro.kernels.spmm import ops as spmm_ops
from repro.kernels.spmm.kernel import spmm_bcsr
from repro.kernels.spmspm.kernel import spmspm_ell


def ensure_virtual_devices(n: int = 4, *, strict: bool = False) -> None:
    """Force >= ``n`` virtual CPU devices (tests / CLI demos on one host).

    Must run before the first jax backend touch; a no-op if XLA_FLAGS
    already forces a count or a real multi-device backend exists.  The env
    flag cannot take effect once the backend has initialized, so if that
    already happened with fewer than ``n`` devices this *warns* (or raises
    under ``strict=True``) instead of silently leaving sharded tests running
    on a single device."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip())
    initialized = False
    try:  # private, but the public API offers no side-effect-free probe
        from jax._src import xla_bridge as _xb
        initialized = bool(getattr(_xb, "_backends", None))
    except Exception:
        initialized = False
    if initialized and jax.local_device_count() < n:
        msg = (f"ensure_virtual_devices({n}): the JAX backend already "
               f"initialized with {jax.local_device_count()} device(s); the "
               "XLA_FLAGS override cannot take effect in this process. "
               "Sharded code will run on fewer devices than requested -- "
               "call ensure_virtual_devices() before any jax API that "
               "touches the backend (or set XLA_FLAGS in the environment).")
        if strict:
            raise RuntimeError(msg)
        warnings.warn(msg, RuntimeWarning, stacklevel=2)


def _interpret_default(interpret: Optional[bool]) -> bool:
    return (not tuning.on_tpu()) if interpret is None else interpret


def auto_mesh(mesh: Optional[Mesh] = None) -> Tuple[Mesh, str]:
    """Resolve (mesh, shard-axis): arg > parallel-context mesh > all devices."""
    if mesh is None:
        from repro.parallel import context as pctx
        mesh = pctx.MESH
    if mesh is None:
        devs = jax.devices()
        mesh = jax.make_mesh((len(devs),), ("data",))
    axis = "data" if "data" in mesh.axis_names else mesh.axis_names[0]
    return mesh, axis


def _pad_dim(x: jax.Array, dim: int, multiple: int, value=0) -> jax.Array:
    pad = (-x.shape[dim]) % multiple
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[dim] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------
# SpMM: N-column partitioning (replicated index stream, sliced dense HBM).
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _sharded_spmm_fn(mesh: Mesh, axis: str, gm: int, bn: int, out_dtype: str,
                     interpret: bool):
    kern = functools.partial(spmm_bcsr, n_block_rows=gm, bn=bn,
                             out_dtype=jnp.dtype(out_dtype), interpret=interpret)
    return jax.jit(compat_shard_map(
        lambda rows, cols, blocks, dense: kern(rows, cols, blocks, dense),
        mesh=mesh,
        in_specs=(P(), P(), P(), P(None, axis)),
        out_specs=P(None, axis),
        check=False,  # pallas_call has no replication/vma rule
    ))


def shard_spmm(a: BCSR, dense: jax.Array, *, mesh: Optional[Mesh] = None,
               bn: Optional[int] = None, out_dtype=jnp.float32,
               interpret: Optional[bool] = None) -> jax.Array:
    """C = A @ dense with dense's N-tiles partitioned across the mesh.

    Handles uneven splits: N is zero-padded up to ``n_dev * bn`` granularity
    and the pad is stripped after the gather, so any N works on any mesh."""
    mesh, axis = auto_mesh(mesh)
    n_dev = mesh.shape[axis]
    interpret = _interpret_default(interpret)
    a = spmm_ops.pad_empty_rows(a)
    K, N = dense.shape
    assert K == a.shape[1], (a.shape, dense.shape)
    bn = spmm_ops._resolve_bn(bn, max(1, N // n_dev), dense.dtype, a.block[1])
    dense = _pad_dim(dense, 1, n_dev * bn)
    gm, _ = a.grid_shape
    fn = _sharded_spmm_fn(mesh, axis, gm, bn, jnp.dtype(out_dtype).name,
                          interpret)
    out = fn(a.block_rows, a.block_cols, a.blocks, dense)
    return out[:, :N]


# ---------------------------------------------------------------------------
# Batched SpMM: batch partitioning (whole problems per device, MoE-style).
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _sharded_spmm_batched_fn(mesh: Mesh, axis: str, gm: int, bn: int,
                             out_dtype: str, interpret: bool):
    kern = functools.partial(spmm_bcsr, n_block_rows=gm, bn=bn,
                             out_dtype=jnp.dtype(out_dtype), interpret=interpret)

    def local(rows, cols, blocks, dense):
        # vmap over this device's slice of the batch; index stream shared.
        return jax.vmap(lambda bl, d: kern(rows, cols, bl, d))(blocks, dense)

    return jax.jit(compat_shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(), P(axis), P(axis)),
        out_specs=P(axis),
        check=False,
    ))


def shard_spmm_batched(a: BatchedBCSR, dense: jax.Array, *,
                       mesh: Optional[Mesh] = None, bn: Optional[int] = None,
                       out_dtype=jnp.float32,
                       interpret: Optional[bool] = None) -> jax.Array:
    """C[b] = A[b] @ dense[b], batch dim partitioned across the mesh.

    ``dense``: (B, K, N) or (K, N) broadcast. The batch is zero-padded up to
    a device multiple (zero blocks x zero dense = zero work rows) and the
    pad stripped after."""
    mesh, axis = auto_mesh(mesh)
    n_dev = mesh.shape[axis]
    interpret = _interpret_default(interpret)
    a = spmm_ops.pad_empty_rows(a)
    B = a.batch
    if dense.ndim == 2:
        dense = jnp.broadcast_to(dense, (B,) + dense.shape)
    assert dense.shape[0] == B and dense.shape[1] == a.shape[2], (
        a.shape, dense.shape)
    N = dense.shape[2]
    bn = spmm_ops._resolve_bn(bn, N, dense.dtype, a.block[1])
    dense = _pad_dim(_pad_dim(dense, 2, bn), 0, n_dev)
    blocks = _pad_dim(a.blocks, 0, n_dev)
    gm, _ = a.grid_shape
    fn = _sharded_spmm_batched_fn(mesh, axis, gm, bn,
                                  jnp.dtype(out_dtype).name, interpret)
    out = fn(a.block_rows, a.block_cols, blocks, dense)
    return out[:B, :, :N]


# ---------------------------------------------------------------------------
# SpMSpM: B-column-stream partitioning (each device owns an output stripe).
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _sharded_spmspm_fn(mesh: Mesh, axis: str, rt: int, ct: int,
                       out_dtype: str, interpret: bool):
    kern = functools.partial(spmspm_ell, rt=rt, ct=ct,
                             out_dtype=jnp.dtype(out_dtype), interpret=interpret)
    return jax.jit(compat_shard_map(
        lambda ak, av, bk, bv: kern(ak, av, bk, bv),
        mesh=mesh,
        in_specs=(P(), P(), P(axis, None), P(axis, None)),
        out_specs=P(None, axis),
        check=False,
    ))


def shard_spmspm(a_keys, a_vals, b_keys, b_vals, *,
                 mesh: Optional[Mesh] = None, rt: Optional[int] = None,
                 ct: Optional[int] = None, out_dtype=jnp.float32,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Sharded sorted-stream intersection: A's row streams replicated, B's
    column streams partitioned; device d computes output columns of its B
    stripe.  R is padded to ``rt`` and C to ``n_dev * ct`` (INVALID keys,
    zero values -- they can never match) and both pads are stripped."""
    mesh, axis = auto_mesh(mesh)
    n_dev = mesh.shape[axis]
    interpret = _interpret_default(interpret)
    ak, av = jnp.asarray(a_keys), jnp.asarray(a_vals)
    bk, bv = jnp.asarray(b_keys), jnp.asarray(b_vals)
    R, C = ak.shape[0], bk.shape[0]
    if rt is None or ct is None:
        trt, tct = tuning.spmspm_tiles(R, max(1, C // n_dev), ak.shape[1],
                                       bk.shape[1], av.dtype)
        rt, ct = rt or trt, ct or tct
    ak = _pad_dim(ak, 0, rt, value=INVALID_KEY)
    av = _pad_dim(av, 0, rt)
    bk = _pad_dim(bk, 0, n_dev * ct, value=INVALID_KEY)
    bv = _pad_dim(bv, 0, n_dev * ct)
    fn = _sharded_spmspm_fn(mesh, axis, rt, ct, jnp.dtype(out_dtype).name,
                            interpret)
    return fn(ak, av, bk, bv)[:R, :C]
