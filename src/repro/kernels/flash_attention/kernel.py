"""Pallas streaming (flash) attention: the LM-side SU-style kernel.

The same Occamy discipline applied to attention: affine K/V tile streams are
double-buffered into VMEM by the Pallas pipeline while the MXU runs
back-to-back (bq x d)(d x bk) products; the online-softmax state (m, l, acc)
lives in VMEM scratch across the KV grid dimension -- the SPM-resident
accumulator. Supports GQA (kv-head sharing), causal masking and sliding
windows (Gemma-3's 5:1 local:global = banded sparsity, same halo discipline
as the stencil kernels).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_offset_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                  acc_ref, *, scale: float, causal: bool, window: int | None,
                  bq: int, bk: int, n_kv_tiles: int):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qi = pl.program_id(2)
    # q_offset: absolute position of this shard's first query row (scalar
    # prefetch) -- lets sequence-sharded callers (shard_map SP) keep exact
    # causal/window masks.
    off = q_offset_ref[0]
    q_pos = off + qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # Tile-level skip: entirely-masked KV tiles cost zero FLOPs (the paper's
    # "only stream useful data" discipline).
    q_lo, q_hi = off + qi * bq, off + qi * bq + bq - 1
    k_lo, k_hi = ki * bk, ki * bk + bk - 1
    live = True
    if causal:
        live = live & (k_lo <= q_hi)
    if window is not None:
        live = live & (k_hi >= q_lo - window + 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)                # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                             # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                    # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_kv_tiles - 1)
    def _final():
        l = l_ref[...]
        safe = jnp.where(l == 0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None, bq: int = 128, bk: int = 128,
                    q_offset=None, interpret: bool = False) -> jax.Array:
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D); Hq % Hkv == 0 (GQA).

    ``q_offset``: absolute position of q row 0 (scalar; default 0) for
    sequence-sharded callers. Returns (B, Hq, Sq, D) in q.dtype.
    Sq % bq == 0, Skv % bk == 0 (ops.py pads).
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    n_kv = Skv // bk
    if q_offset is None:
        q_offset = jnp.zeros((1,), jnp.int32)
    else:
        q_offset = jnp.asarray(q_offset, jnp.int32).reshape(1)
    kern = functools.partial(_flash_kernel, scale=scale, causal=causal,
                             window=window, bq=bq, bk=bk, n_kv_tiles=n_kv)
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, Hq, Sq // bq, n_kv),
            in_specs=[
                pl.BlockSpec((1, 1, bq, D),
                             lambda b, h, qi, ki, off: (b, h, qi, 0)),
                pl.BlockSpec((1, 1, bk, D),
                             lambda b, h, qi, ki, off: (b, h // g, ki, 0)),
                pl.BlockSpec((1, 1, bk, D),
                             lambda b, h, qi, ki, off: (b, h // g, ki, 0)),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, bq, D), lambda b, h, qi, ki, off: (b, h, qi, 0)),
            scratch_shapes=[
                pltpu.VMEM((bq, 1), jnp.float32),
                pltpu.VMEM((bq, 1), jnp.float32),
                pltpu.VMEM((bq, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        interpret=interpret,
    )(q_offset, q, k, v)
