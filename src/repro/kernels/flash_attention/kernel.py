"""Pallas streaming (flash) attention: the LM-side SU-style kernel.

The same Occamy discipline applied to attention: affine K/V tile streams are
double-buffered into VMEM by the Pallas pipeline while the MXU runs
back-to-back (bq x d)(d x bk) products; the online-softmax state (m, l, acc)
lives in VMEM scratch across the KV grid dimension -- the SPM-resident
accumulator. Supports GQA (kv-head sharing), causal masking and sliding
windows (Gemma-3's 5:1 local:global = banded sparsity, same halo discipline
as the stencil kernels).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.masks import KIND_CAUSAL, KIND_WINDOW, NEG_INF
from repro.kernels import streamwalk


def _flash_kernel(q_offset_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                  acc_ref, *, scale: float, causal: bool, window: int | None,
                  bq: int, bk: int, n_kv_tiles: int):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qi = pl.program_id(2)
    # q_offset: absolute position of this shard's first query row (scalar
    # prefetch) -- lets sequence-sharded callers (shard_map SP) keep exact
    # causal/window masks.
    off = q_offset_ref[0]
    q_pos = off + qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # Tile-level skip: entirely-masked KV tiles cost zero FLOPs (the paper's
    # "only stream useful data" discipline).
    q_lo, q_hi = off + qi * bq, off + qi * bq + bq - 1
    k_lo, k_hi = ki * bk, ki * bk + bk - 1
    live = True
    if causal:
        live = live & (k_lo <= q_hi)
    if window is not None:
        live = live & (k_hi >= q_lo - window + 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)                # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                             # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                    # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_kv_tiles - 1)
    def _final():
        l = l_ref[...]
        safe = jnp.where(l == 0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None, bq: int = 128, bk: int = 128,
                    q_offset=None, interpret: bool = False) -> jax.Array:
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D); Hq % Hkv == 0 (GQA).

    ``q_offset``: absolute position of q row 0 (scalar; default 0) for
    sequence-sharded callers. Returns (B, Hq, Sq, D) in q.dtype.
    Sq % bq == 0, Skv % bk == 0 (ops.py pads).
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    n_kv = Skv // bk
    if q_offset is None:
        q_offset = jnp.zeros((1,), jnp.int32)
    else:
        q_offset = jnp.asarray(q_offset, jnp.int32).reshape(1)
    kern = functools.partial(_flash_kernel, scale=scale, causal=causal,
                             window=window, bq=bq, bk=bk, n_kv_tiles=n_kv)
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, Hq, Sq // bq, n_kv),
            in_specs=[
                pl.BlockSpec((1, 1, bq, D),
                             lambda b, h, qi, ki, off: (b, h, qi, 0)),
                pl.BlockSpec((1, 1, bk, D),
                             lambda b, h, qi, ki, off: (b, h // g, ki, 0)),
                pl.BlockSpec((1, 1, bk, D),
                             lambda b, h, qi, ki, off: (b, h // g, ki, 0)),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, bq, D), lambda b, h, qi, ki, off: (b, h, qi, 0)),
            scratch_shapes=[
                pltpu.VMEM((bq, 1), jnp.float32),
                pltpu.VMEM((bq, 1), jnp.float32),
                pltpu.VMEM((bq, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        interpret=interpret,
    )(q_offset, q, k, v)


# ---------------------------------------------------------------------------
# Block-sparse attention: the BCSR stream-walk discipline applied to the KV
# grid.  A BlockMask (core.masks) lowers to sorted (row, col, kind) streams;
# the sparse kernel walks visible tiles only, the masked-dense kernel walks
# the full grid gated by the same per-tile kinds (the parity baseline).  Both
# share _tile_update, so they are bit-identical per construction.
# ---------------------------------------------------------------------------

def _tile_update(q, k, v, m_ref, l_ref, acc_ref, *, scale: float, kind,
                 q_pos, k_pos, window: int | None, skv: int):
    """One online-softmax update of the resident (m, l, acc) state with one
    (bq, bk) tile, refined per the tile's kind bits (core.masks).

    Dead-entry safety: with ``p = where(mask, exp(s - m_new), 0)`` a fully
    masked tile is an *exact* no-op -- m_new == m_prev, alpha == exp(0) == 1,
    p == 0 -- so bucket-padding entries and empty rows change nothing, and
    for live tiles the form is bit-identical to the classic
    exp-of-NEG_INF-masked update (the masked exp underflows to +0.0).
    """
    qf = q.astype(jnp.float32) * scale                 # (bq, d)
    kf = k.astype(jnp.float32)                         # (bk, d)
    vf = v.astype(jnp.float32)                         # (bk, d)
    s = jax.lax.dot_general(qf, kf, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (bq, bk)
    mask = k_pos < skv                                 # KV tail validity
    mask &= jnp.where((kind & KIND_CAUSAL) != 0, q_pos >= k_pos, True)
    if window is not None:
        mask &= jnp.where((kind & KIND_WINDOW) != 0,
                          (q_pos - k_pos) < window, True)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)       # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                    # (bq, 1)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p.astype(vf.dtype), vf, preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def _finalize(o_ref, l_ref, acc_ref):
    l = l_ref[...]
    safe = jnp.where(l == 0, 1.0, l)
    o_ref[0, 0] = (acc_ref[...] / safe).astype(o_ref.dtype)


def _flash_masked_kernel(kinds_ref, q_offset_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, scale: float,
                         window: int | None, bq: int, bk: int, skv: int,
                         n_kv_tiles: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kind = kinds_ref[qi, ki]
    off = q_offset_ref[0]
    q_pos = off + qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    @pl.when(kind >= 0)
    def _compute():
        _tile_update(q_ref[0, 0], k_ref[0, 0], v_ref[0, 0], m_ref, l_ref,
                     acc_ref, scale=scale, kind=kind, q_pos=q_pos,
                     k_pos=k_pos, window=window, skv=skv)

    @pl.when(ki == n_kv_tiles - 1)
    def _final():
        _finalize(o_ref, l_ref, acc_ref)


def flash_attention_masked(q: jax.Array, k: jax.Array, v: jax.Array,
                           tile_kinds: jax.Array, *, skv: int,
                           window: int | None = None,
                           scale: float | None = None, q_offset=None,
                           interpret: bool = False) -> jax.Array:
    """Dense-grid flash over a per-tile kind map: every KV tile is stepped,
    dead tiles (kind < 0) skip compute (the old whole-tile -1e30 masking,
    now stream-shaped).  The parity baseline for the sparse walk.

    q: (B, Hq, Sq_pad, D) with Sq_pad % bq == 0; k/v: (B, Hkv, Skv_pad, D)
    with Skv_pad % bk == 0; ``skv`` is the true (unpadded) KV length.
    tile_kinds: (Sq_pad//bq, Skv_pad//bk) int32 (BlockMask.tile_kinds).
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv_pad, _ = k.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    n_q, n_kv = tile_kinds.shape
    assert Sq % n_q == 0 and Skv_pad % n_kv == 0
    bq, bk = Sq // n_q, Skv_pad // n_kv
    scale = scale if scale is not None else D ** -0.5
    if q_offset is None:
        q_offset = jnp.zeros((1,), jnp.int32)
    else:
        q_offset = jnp.asarray(q_offset, jnp.int32).reshape(1)
    kern = functools.partial(_flash_masked_kernel, scale=scale, window=window,
                             bq=bq, bk=bk, skv=skv, n_kv_tiles=n_kv)
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # tile_kinds, q_offset
            grid=(B, Hq, n_q, n_kv),
            in_specs=[
                pl.BlockSpec((1, 1, bq, D),
                             lambda b, h, qi, ki, kinds, off: (b, h, qi, 0)),
                pl.BlockSpec((1, 1, bk, D),
                             lambda b, h, qi, ki, kinds, off:
                             (b, h // g, ki, 0)),
                pl.BlockSpec((1, 1, bk, D),
                             lambda b, h, qi, ki, kinds, off:
                             (b, h // g, ki, 0)),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, bq, D), lambda b, h, qi, ki, kinds, off: (b, h, qi, 0)),
            scratch_shapes=[
                pltpu.VMEM((bq, 1), jnp.float32),
                pltpu.VMEM((bq, 1), jnp.float32),
                pltpu.VMEM((bq, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        interpret=interpret,
    )(jnp.asarray(tile_kinds, jnp.int32), q_offset, q, k, v)


def _flash_sparse_kernel(rows_ref, cols_ref, kinds_ref, q_offset_ref, q_ref,
                         k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                         scale: float, window: int | None, bq: int, bk: int,
                         skv: int, nnzb: int):
    i = pl.program_id(2)  # position in the visible-tile stream

    @pl.when(streamwalk.row_start(rows_ref, i))
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kind = kinds_ref[i]
    off = q_offset_ref[0]
    q_pos = off + rows_ref[i] * bq + \
        jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = cols_ref[i] * bk + \
        jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    @pl.when(kind >= 0)  # bucket-pad / empty-row entries are exact no-ops
    def _compute():
        _tile_update(q_ref[0, 0], k_ref[0, 0], v_ref[0, 0], m_ref, l_ref,
                     acc_ref, scale=scale, kind=kind, q_pos=q_pos,
                     k_pos=k_pos, window=window, skv=skv)

    @pl.when(streamwalk.row_end(rows_ref, i, nnzb))
    def _final():
        _finalize(o_ref, l_ref, acc_ref)


def flash_attention_sparse(q: jax.Array, k: jax.Array, v: jax.Array,
                           rows: jax.Array, cols: jax.Array,
                           kinds: jax.Array, *, skv: int,
                           window: int | None = None,
                           scale: float | None = None, bq: int = 128,
                           bk: int = 128, q_offset=None,
                           interpret: bool = False) -> jax.Array:
    """Flash attention walking a BlockMask's visible-tile stream.

    The KV grid dimension is the *stream walk*: scalar-prefetched
    (row, col, kind) indices (``BlockMask.lower()``, bucket-padded to a
    power of two like the MoE dispatch stream) steer the K/V BlockSpec DMA
    (SU indirection) while the online-softmax (m, l, acc) state stays
    VMEM-resident across each q-row's run.  Whole-tile masking disappears --
    only intra-tile causal/window/tail edges remain, selected per tile by
    the kind bits.

    q: (B, Hq, Sq_pad, D), Sq_pad % bq == 0; k/v: (B, Hkv, Skv_pad, D),
    Skv_pad % bk == 0; ``skv`` is the true KV length.  rows/cols/kinds:
    (capacity,) int32, sorted by (row, col), every q-tile row present.
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv_pad, _ = k.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    assert Sq % bq == 0 and Skv_pad % bk == 0
    nnzb = rows.shape[0]
    scale = scale if scale is not None else D ** -0.5
    if q_offset is None:
        q_offset = jnp.zeros((1,), jnp.int32)
    else:
        q_offset = jnp.asarray(q_offset, jnp.int32).reshape(1)
    walk = streamwalk.StreamWalk(outer=2)  # (b, h) outer, stream axis last
    kern = functools.partial(_flash_sparse_kernel, scale=scale, window=window,
                             bq=bq, bk=bk, skv=skv, nnzb=nnzb)
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,  # rows, cols, kinds, q_offset
            grid=walk.grid((B, Hq), nnzb),
            in_specs=[
                # Q / output revisit the sorted row stream: the tile stays
                # resident across its run of KV blocks.
                walk.row_spec((1, 1, bq, D),
                              lambda o, r, t: (o[0], o[1], r, 0)),
                # K/V: the indirect stream -- the prefetched block-col index
                # steers which KV tile the pipeline double-buffers next.
                walk.indexed_spec((1, 1, bk, D),
                                  lambda o, c, t: (o[0], o[1] // g, c, 0)),
                walk.indexed_spec((1, 1, bk, D),
                                  lambda o, c, t: (o[0], o[1] // g, c, 0)),
            ],
            out_specs=walk.row_spec((1, 1, bq, D),
                                    lambda o, r, t: (o[0], o[1], r, 0)),
            scratch_shapes=[
                pltpu.VMEM((bq, 1), jnp.float32),
                pltpu.VMEM((bq, 1), jnp.float32),
                pltpu.VMEM((bq, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        interpret=interpret,
    )(jnp.asarray(rows, jnp.int32), jnp.asarray(cols, jnp.int32),
      jnp.asarray(kinds, jnp.int32), q_offset, q, k, v)
