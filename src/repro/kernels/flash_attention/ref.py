"""Pure-jnp oracle for flash attention (materialized-scores softmax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.masks import NEG_INF


def attention_ref(q, k, v, *, causal=True, window=None, scale=None,
                  mask=None):
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D). Full-score reference.

    ``mask``: a ``core.masks.BlockMask`` (its ``dense_mask()`` oracle is
    used, overriding ``causal``/``window``) or a dense boolean (Sq, Skv)
    array.
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if mask is not None:
        dense = mask.dense_mask() if hasattr(mask, "dense_mask") else mask
        mask = jnp.asarray(dense, bool)
    else:
        q_pos = jnp.arange(Sq)[:, None]
        k_pos = jnp.arange(Skv)[None, :]
        mask = jnp.ones((Sq, Skv), bool)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # Rows with no visible keys (can happen under padding) -> zero output.
    any_visible = mask.any(axis=-1)[None, None, :, None]
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return jnp.where(any_visible, out, 0.0).astype(q.dtype)
