"""Public flash-attention API: padding, dtype policy, kernel dispatch.

Tile lengths default to the autotune table (``repro.kernels.tuning``, ops
``"flash"`` / ``"flash_sparse"``) instead of hardcoded constants; pass
``bq=`` / ``bk=`` to override.

Block-sparse dispatch: ``attention(..., mask=BlockMask)`` routes through the
stream-walk kernel (``mask_impl="sparse"``), the masked full-grid kernel
(``"dense"``, the parity baseline) or the jnp oracle (``"ref"``).  The mask
lowers to its bucketed index stream at trace time (host numpy on static
shapes), so recompiles are keyed on (bucketed stream capacity x tile/window
statics), not on pattern contents -- the PR-3/6 bucket law.

Reference fallbacks are *explicit*: the O(S^2) materialized oracle only runs
when ``fallback="ref"`` permits it, every use is counted
(:func:`fallback_count`), and ``fallback="error"`` turns the silent slow
path into a hard failure for production traffic.
"""
from __future__ import annotations

import collections
import functools

import jax
import jax.numpy as jnp

from repro.core.masks import NEG_INF, BlockMask
from repro.kernels import tuning
from repro.kernels.flash_attention.kernel import (flash_attention as _kernel,
                                                  flash_attention_masked,
                                                  flash_attention_sparse)
from repro.kernels.flash_attention.ref import attention_ref

# ------------------------------------------------------------------- state
# Reference-oracle fallback accounting (satellite: no silent O(S^2) paths).
_FALLBACKS = collections.Counter()
# Distinct compiled-geometry keys seen by the masked paths -- the recompile
# accounting surface (pattern signature x bucket bound).
_MASK_SIGNATURES = set()


def fallback_count() -> int:
    """Total attention_ref fallbacks since the last reset."""
    return sum(_FALLBACKS.values())


def fallback_reasons() -> dict:
    return dict(_FALLBACKS)


def reset_fallbacks() -> None:
    _FALLBACKS.clear()


def mask_signatures() -> frozenset:
    """Compiled-geometry keys the masked kernels have been traced with; its
    size bounds the number of mask-path recompiles."""
    return frozenset(_MASK_SIGNATURES)


def reset_mask_signatures() -> None:
    _MASK_SIGNATURES.clear()


def _note_fallback(reason: str, fallback: str):
    if fallback == "error":
        raise RuntimeError(
            f"attention would fall back to the O(S^2) reference ({reason}) "
            f"but fallback='error' forbids it")
    if fallback != "ref":
        raise ValueError(f"fallback must be 'ref' or 'error', got {fallback!r}")
    _FALLBACKS[reason] += 1


# ---------------------------------------------------------------- dispatch
def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              bq: int | None = None, bk: int | None = None,
              interpret: bool = False, use_kernel: bool = True,
              mask: BlockMask | None = None, mask_impl: str = "sparse",
              fallback: str = "ref") -> jax.Array:
    """Streaming attention with GQA + causal/sliding-window/BlockMask masks.

    Pads Sq/Skv up to tile multiples; returns (B, Hq, Sq, D) in q.dtype.
    ``bq=None`` / ``bk=None`` (default) consult the autotune table -- the
    lookup happens *eagerly here*, outside the jitted body, so a
    ``tuning.register`` (e.g. from a measured sweep) takes effect on the
    next call instead of being baked into an already-compiled program.

    ``mask``: a ``core.masks.BlockMask`` routes through the block-sparse
    stream walk (``mask_impl="sparse"``), the masked dense grid
    (``"dense"``) or the jnp oracle (``"ref"``); ``causal``/``window`` are
    ignored in favor of the mask's own refinements.

    ``use_kernel=False`` routes to the jnp reference (used on backends where
    Pallas is unavailable and for A/B testing); with ``fallback="error"``
    any reference routing -- explicit or shape-forced -- raises instead.
    """
    if mask is not None:
        return _attention_masked(q, k, v, mask, impl=mask_impl,
                                 interpret=interpret, fallback=fallback)
    if bq is None or bk is None:
        Sq, D = q.shape[2], q.shape[3]
        tbq, tbk = tuning.flash_tiles(Sq, k.shape[2], D, q.dtype)
        bq, bk = bq or tbq, bk or tbk
    # The fallback decision happens *eagerly* (shapes are static here): a
    # counter bumped inside the jitted body would only fire at trace time.
    if not use_kernel:
        _note_fallback("use_kernel=False", fallback)
        return _ref_jit(q, k, v, causal=causal, window=window)
    Sq, Skv = q.shape[2], k.shape[2]
    bk_eff = min(bk, Skv) if Skv % min(bk, Skv) == 0 else bk
    if not causal and (-Skv) % bk_eff:
        # Padded KV columns must not attend; under causal=True they sit
        # outside the horizon (k_pos >= Skv > any real q_pos), but the
        # non-causal ragged case needs explicit masking -> reference.
        _note_fallback("noncausal_kv_pad", fallback)
        return _ref_jit(q, k, v, causal=causal, window=window)
    return _attention_jit(q, k, v, causal=causal, window=window, bq=bq,
                          bk=bk, interpret=interpret)


_ref_jit = jax.jit(attention_ref, static_argnames=("causal", "window",
                                                   "scale"))


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def _attention_jit(q, k, v, *, causal, window, bq, bk, interpret):
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    bq_eff = min(bq, Sq) if Sq % min(bq, Sq) == 0 else bq
    bk_eff = min(bk, Skv) if Skv % min(bk, Skv) == 0 else bk
    qp = (-Sq) % bq_eff
    kp = (-Skv) % bk_eff
    qq, kk, vv = q, k, v
    if qp:
        qq = jnp.pad(q, ((0, 0), (0, 0), (0, qp), (0, 0)))
    if kp:
        kk = jnp.pad(k, ((0, 0), (0, 0), (0, kp), (0, 0)))
        vv = jnp.pad(v, ((0, 0), (0, 0), (0, kp), (0, 0)))
    out = _kernel(qq, kk, vv, causal=causal, window=window, bq=bq_eff,
                  bk=bk_eff, interpret=interpret)
    return out[:, :, :Sq, :]


# ------------------------------------------------------- BlockMask dispatch
@functools.partial(jax.jit, static_argnames=("window", "skv", "bq", "bk",
                                             "sq", "interpret"))
def _sparse_jit(q, k, v, rows, cols, kinds, off, *, window, skv, bq, bk, sq,
                interpret):
    qp = (-sq) % bq
    kp = (-skv) % bk
    qq, kk, vv = q, k, v
    if qp:
        qq = jnp.pad(q, ((0, 0), (0, 0), (0, qp), (0, 0)))
    if kp:
        kk = jnp.pad(k, ((0, 0), (0, 0), (0, kp), (0, 0)))
        vv = jnp.pad(v, ((0, 0), (0, 0), (0, kp), (0, 0)))
    out = flash_attention_sparse(qq, kk, vv, rows, cols, kinds, skv=skv,
                                 window=window, bq=bq, bk=bk, q_offset=off,
                                 interpret=interpret)
    return out[:, :, :sq, :]


@functools.partial(jax.jit, static_argnames=("window", "skv", "bq", "bk",
                                             "sq", "interpret"))
def _masked_jit(q, k, v, kinds_map, off, *, window, skv, bq, bk, sq,
                interpret):
    n_q, n_kv = kinds_map.shape
    qp = n_q * bq - sq
    kp = n_kv * bk - skv
    qq, kk, vv = q, k, v
    if qp:
        qq = jnp.pad(q, ((0, 0), (0, 0), (0, qp), (0, 0)))
    if kp:
        kk = jnp.pad(k, ((0, 0), (0, 0), (0, kp), (0, 0)))
        vv = jnp.pad(v, ((0, 0), (0, 0), (0, kp), (0, 0)))
    out = flash_attention_masked(qq, kk, vv, kinds_map, skv=skv,
                                 window=window, q_offset=off,
                                 interpret=interpret)
    return out[:, :, :sq, :]


def _attention_masked(q, k, v, mask: BlockMask, *, impl: str,
                      interpret: bool, fallback: str = "ref") -> jax.Array:
    B, Hq, Sq, D = q.shape
    Skv = k.shape[2]
    assert mask.sq == Sq and mask.skv == Skv, \
        (mask.sq, mask.skv, Sq, Skv)
    off = jnp.asarray([mask.q_offset], jnp.int32)
    if impl == "ref":
        _note_fallback("mask_impl=ref", fallback)
        return attention_ref(q, k, v, mask=mask)
    if impl == "dense":
        kinds_map = jnp.asarray(mask.tile_kinds, jnp.int32)
        _MASK_SIGNATURES.add(("dense", q.shape, k.shape, mask.bq, mask.bk,
                              mask.window, Sq, Skv))
        return _masked_jit(q, k, v, kinds_map, off, window=mask.window,
                           skv=Skv, bq=mask.bq, bk=mask.bk, sq=Sq,
                           interpret=interpret)
    if impl != "sparse":
        raise ValueError(f"mask_impl must be sparse|dense|ref, got {impl!r}")
    stream = mask.lower(bucket=True)
    _MASK_SIGNATURES.add(("sparse", q.shape, k.shape, mask.bq, mask.bk,
                          mask.window, stream.capacity, Sq, Skv))
    return _sparse_jit(q, k, v, jnp.asarray(stream.rows),
                       jnp.asarray(stream.cols), jnp.asarray(stream.kinds),
                       off, window=mask.window, skv=Skv, bq=mask.bq,
                       bk=mask.bk, sq=Sq, interpret=interpret)


def decode_attention(q1, k_cache, v_cache, *, kv_len=None, window=None,
                     interpret: bool = False, use_kernel: bool = False):
    """One-token decode: q1 (B, Hq, 1, D) against a (B, Hkv, S, D) cache.

    Decode is memory-bound (one Q row streams the whole cache); the jnp path
    lowers to a clean gather+reduce that XLA fuses, so the kernel is optional.
    ``kv_len`` masks cache tail beyond the current length.

    Arithmetic is *prefix-aligned* with ``chunked_attention`` (the prefill
    path): the narrow-dtype cast applies to the UNNORMALIZED ``exp(s - m)``
    weights and the f32-accumulated PV product is divided by the f32 row sum
    afterwards.  Normalizing before the cast quantizes a different quantity
    than prefill quantizes, which is enough hidden-state noise (~1 bf16 ulp
    per layer) to flip near-tie MoE router argmaxes between decode and
    prefill (the old `test_decode_matches_prefill[llama4-scout]` failure).
    With the ordering aligned, stepwise decode reproduces prefill logits
    bit-for-bit on the smoke configs.
    """
    B, Hq, _, D = q1.shape
    _, Hkv, S, _ = k_cache.shape
    g = Hq // Hkv
    scale = D ** -0.5
    # repeat-free GQA, narrow-dtype streams, f32 accumulate (ExSdotp pattern)
    qg = (q1 * scale).astype(k_cache.dtype).reshape(B, Hkv, g, 1, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k_cache,
                   preferred_element_type=jnp.float32)
    pos = jnp.arange(S)[None, None, None, None, :]
    if kv_len is not None:
        limit = jnp.asarray(kv_len).reshape(-1, 1, 1, 1, 1)
        s = jnp.where(pos < limit, s, NEG_INF)
        if window is not None:
            s = jnp.where(pos >= limit - window, s, NEG_INF)
    elif window is not None:
        s = jnp.where(pos >= S - window, s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)                      # unnormalized, like the chunked path
    l = p.sum(axis=-1, keepdims=True)       # f32 row sum
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    out = out / jnp.where(l == 0, 1.0, l)
    return out.reshape(B, Hq, 1, D).astype(q1.dtype)


def flops(B, Hq, Sq, Skv, D, causal=True) -> int:
    """Attention FLOPs (2 matmuls), halved under causal masking."""
    f = 4 * B * Hq * Sq * Skv * D
    return f // 2 if causal else f
