"""Public flash-attention API: padding, dtype policy, kernel dispatch.

Tile lengths default to the autotune table (``repro.kernels.tuning``, op
``"flash"``) instead of hardcoded constants; pass ``bq=`` / ``bk=`` to
override."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import tuning
from repro.kernels.flash_attention.kernel import flash_attention as _kernel
from repro.kernels.flash_attention.ref import attention_ref


def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              bq: int | None = None, bk: int | None = None,
              interpret: bool = False,
              use_kernel: bool = True) -> jax.Array:
    """Streaming attention with GQA + causal/sliding-window masks.

    Pads Sq/Skv up to tile multiples; returns (B, Hq, Sq, D) in q.dtype.
    ``bq=None`` / ``bk=None`` (default) consult the autotune table -- the
    lookup happens *eagerly here*, outside the jitted body, so a
    ``tuning.register`` (e.g. from a measured sweep) takes effect on the
    next call instead of being baked into an already-compiled program.
    ``use_kernel=False`` routes to the jnp reference (used on backends where
    Pallas is unavailable and for A/B testing).
    """
    if bq is None or bk is None:
        Sq, D = q.shape[2], q.shape[3]
        tbq, tbk = tuning.flash_tiles(Sq, k.shape[2], D, q.dtype)
        bq, bk = bq or tbq, bk or tbk
    return _attention_jit(q, k, v, causal=causal, window=window, bq=bq,
                          bk=bk, interpret=interpret, use_kernel=use_kernel)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret", "use_kernel"))
def _attention_jit(q, k, v, *, causal, window, bq, bk, interpret,
                   use_kernel):
    if not use_kernel:
        return attention_ref(q, k, v, causal=causal, window=window)
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    bq_eff = min(bq, Sq) if Sq % min(bq, Sq) == 0 else bq
    bk_eff = min(bk, Skv) if Skv % min(bk, Skv) == 0 else bk
    qp = (-Sq) % bq_eff
    kp = (-Skv) % bk_eff
    qq, kk, vv = q, k, v
    if qp:
        qq = jnp.pad(q, ((0, 0), (0, 0), (0, qp), (0, 0)))
    if kp:
        kk = jnp.pad(k, ((0, 0), (0, 0), (0, kp), (0, 0)))
        vv = jnp.pad(v, ((0, 0), (0, 0), (0, kp), (0, 0)))
    # Padded KV columns must not attend: push them outside the causal horizon
    # by masking via an additive -inf on padded keys is equivalent to the
    # causal mask when padding sits at the tail and Sq_pad >= Skv positions;
    # for the general case we mask padded keys with a window trick: padded
    # keys have k_pos >= Skv > any real q_pos under causal=True. For
    # non-causal use, fall back to explicit masking in the reference.
    if not causal and kp:
        return attention_ref(q, k, v, causal=causal, window=window)
    out = _kernel(qq, kk, vv, causal=causal, window=window, bq=bq_eff,
                  bk=bk_eff, interpret=interpret)
    return out[:, :, :Sq, :]


def decode_attention(q1, k_cache, v_cache, *, kv_len=None, window=None,
                     interpret: bool = False, use_kernel: bool = False):
    """One-token decode: q1 (B, Hq, 1, D) against a (B, Hkv, S, D) cache.

    Decode is memory-bound (one Q row streams the whole cache); the jnp path
    lowers to a clean gather+reduce that XLA fuses, so the kernel is optional.
    ``kv_len`` masks cache tail beyond the current length.

    Arithmetic is *prefix-aligned* with ``chunked_attention`` (the prefill
    path): the narrow-dtype cast applies to the UNNORMALIZED ``exp(s - m)``
    weights and the f32-accumulated PV product is divided by the f32 row sum
    afterwards.  Normalizing before the cast quantizes a different quantity
    than prefill quantizes, which is enough hidden-state noise (~1 bf16 ulp
    per layer) to flip near-tie MoE router argmaxes between decode and
    prefill (the old `test_decode_matches_prefill[llama4-scout]` failure).
    With the ordering aligned, stepwise decode reproduces prefill logits
    bit-for-bit on the smoke configs.
    """
    B, Hq, _, D = q1.shape
    _, Hkv, S, _ = k_cache.shape
    g = Hq // Hkv
    scale = D ** -0.5
    # repeat-free GQA, narrow-dtype streams, f32 accumulate (ExSdotp pattern)
    qg = (q1 * scale).astype(k_cache.dtype).reshape(B, Hkv, g, 1, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k_cache,
                   preferred_element_type=jnp.float32)
    pos = jnp.arange(S)[None, None, None, None, :]
    if kv_len is not None:
        limit = jnp.asarray(kv_len).reshape(-1, 1, 1, 1, 1)
        s = jnp.where(pos < limit, s, -1e30)
        if window is not None:
            s = jnp.where(pos >= limit - window, s, -1e30)
    elif window is not None:
        s = jnp.where(pos >= S - window, s, -1e30)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)                      # unnormalized, like the chunked path
    l = p.sum(axis=-1, keepdims=True)       # f32 row sum
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    out = out / jnp.where(l == 0, 1.0, l)
    return out.reshape(B, Hq, 1, D).astype(q1.dtype)


def flops(B, Hq, Sq, Skv, D, causal=True) -> int:
    """Attention FLOPs (2 matmuls), halved under causal masking."""
    f = 4 * B * Hq * Sq * Skv * D
    return f // 2 if causal else f
