"""Pure-jnp oracle for the stencil kernels (shifted-slice formulation)."""
from __future__ import annotations

import jax

from repro.core.stencils import StencilSpec, apply_reference


def stencil_ref(grid_in: jax.Array, spec: StencilSpec) -> jax.Array:
    """Reference: valid-interior stencil application (any ndim)."""
    return apply_reference(spec, grid_in)
