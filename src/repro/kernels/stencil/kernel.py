"""Pallas stencil kernels: halo-overlapped BlockSpec streaming (SU analogue).

The SU mechanism being reproduced: Occamy programs two affine streams (grid
reads, result writes) so the FPU executes one FMA per tap per cycle with zero
address arithmetic. Here the Pallas grid pipeline streams overlapping
(tile + 2*halo) VMEM blocks (element-offset ``pl.unblocked`` indexing) while the unrolled
shifted-slice FMA chain inside the kernel is the exact analogue of Fig. 5's
"continuous FMA execution". Double-buffering of HBM->VMEM tiles is Pallas'
automatic pipelining -- Occamy's DMA-core double buffering.

Tiling: last dim is lanes (128-aligned), second-to-last sublanes (8-aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.stencils import StencilSpec


def _overlap_spec(elem_shape, index_map):
    """Element-offset (overlapping halo window) BlockSpec across jax
    versions: ``pl.Element`` on newer jax, ``indexing_mode=pl.unblocked``
    on 0.4.x (same semantics -- index_map returns element offsets)."""
    if hasattr(pl, "Element"):
        return pl.BlockSpec(tuple(pl.Element(s) for s in elem_shape), index_map)
    return pl.BlockSpec(elem_shape, index_map, indexing_mode=pl.unblocked)


def _stencil_kernel_2d(x_ref, o_ref, *, spec: StencilSpec, th: int, tw: int):
    r = spec.radius
    acc = jnp.zeros((th, tw), jnp.float32)
    # Unrolled FMA chain: one shifted VMEM read per tap, no address arithmetic.
    for off, c in zip(spec.offsets, spec.coeffs):
        dy, dx = off
        tap = x_ref[r + dy : r + dy + th, r + dx : r + dx + tw]
        acc += c * tap.astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def _stencil_kernel_3d(x_ref, o_ref, *, spec: StencilSpec, tz: int, ty: int, tx: int):
    r = spec.radius
    acc = jnp.zeros((tz, ty, tx), jnp.float32)
    for off, c in zip(spec.offsets, spec.coeffs):
        dz, dy, dx = off
        tap = x_ref[
            r + dz : r + dz + tz,
            r + dy : r + dy + ty,
            r + dx : r + dx + tx,
        ]
        acc += c * tap.astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def stencil_2d(grid_in: jax.Array, spec: StencilSpec, *, tile=(64, 128),
               interpret: bool = False) -> jax.Array:
    """Apply ``spec`` to ``grid_in`` (halo included); returns the interior.

    ``grid_in``: (H + 2r, W + 2r); output (H, W). H % tile[0] == 0 etc.
    (padding is handled by ops.apply).
    """
    r = spec.radius
    th, tw = tile
    H = grid_in.shape[0] - 2 * r
    W = grid_in.shape[1] - 2 * r
    assert H % th == 0 and W % tw == 0, (grid_in.shape, tile)
    kern = functools.partial(_stencil_kernel_2d, spec=spec, th=th, tw=tw)
    return pl.pallas_call(
        kern,
        grid=(H // th, W // tw),
        in_specs=[_overlap_spec(
            (th + 2 * r, tw + 2 * r),
            lambda i, j: (i * th, j * tw),
        )],
        out_specs=pl.BlockSpec((th, tw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((H, W), grid_in.dtype),
        interpret=interpret,
    )(grid_in)


def stencil_3d(grid_in: jax.Array, spec: StencilSpec, *, tile=(8, 16, 128),
               interpret: bool = False) -> jax.Array:
    """3-D variant (j3d7pt / j3d27pt -- the paper's 83%-utilization kernel)."""
    r = spec.radius
    tz, ty, tx = tile
    Z = grid_in.shape[0] - 2 * r
    Y = grid_in.shape[1] - 2 * r
    X = grid_in.shape[2] - 2 * r
    assert Z % tz == 0 and Y % ty == 0 and X % tx == 0, (grid_in.shape, tile)
    kern = functools.partial(_stencil_kernel_3d, spec=spec, tz=tz, ty=ty, tx=tx)
    return pl.pallas_call(
        kern,
        grid=(Z // tz, Y // ty, X // tx),
        in_specs=[_overlap_spec(
            (tz + 2 * r, ty + 2 * r, tx + 2 * r),
            lambda i, j, k: (i * tz, j * ty, k * tx),
        )],
        out_specs=pl.BlockSpec((tz, ty, tx), lambda i, j, k: (i, j, k)),
        out_shape=jax.ShapeDtypeStruct((Z, Y, X), grid_in.dtype),
        interpret=interpret,
    )(grid_in)
