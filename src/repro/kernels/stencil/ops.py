"""Jitted public API for the Pallas stencil kernels (padding + dispatch)."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.stencils import StencilSpec
from repro.kernels import tuning
from repro.kernels.stencil.kernel import stencil_2d, stencil_3d


def _padded_tiles(interior: Tuple[int, ...], tile: Tuple[int, ...]):
    return tuple(-(-n // t) * t for n, t in zip(interior, tile))


@functools.partial(jax.jit, static_argnames=("spec", "tile", "interpret"))
def apply(grid_in: jax.Array, spec: StencilSpec, *, tile: Tuple[int, ...] | None = None,
          interpret: bool = False) -> jax.Array:
    """Apply ``spec`` to a halo-carrying grid; handles non-tile-aligned shapes.

    ``grid_in`` has shape interior + 2*radius per dim; returns the interior.
    """
    r = spec.radius
    ndim = spec.ndim
    assert grid_in.ndim == ndim
    interior = tuple(s - 2 * r for s in grid_in.shape)
    # Tile selection: explicit arg > autotune table (per dtype / platform).
    tile = tile or tuning.stencil_tile(interior, grid_in.dtype)
    # Shrink tiles that exceed the (already halo-less) interior.
    tile = tuple(min(t, -(-n // 8) * 8 if i < ndim - 1 else -(-n // 128) * 128)
                 for i, (t, n) in enumerate(zip(tile, interior)))
    padded = _padded_tiles(interior, tile)
    pad = [(0, p - n) for n, p in zip(interior, padded)]
    x = jnp.pad(grid_in, pad)
    fn = stencil_2d if ndim == 2 else stencil_3d
    out = fn(x, spec, tile=tile, interpret=interpret)
    return out[tuple(slice(0, n) for n in interior)]


def flops(spec: StencilSpec, interior: Tuple[int, ...]) -> int:
    """FLOPs of one application (2 per tap per point, the paper's convention)."""
    n = 1
    for s in interior:
        n *= s
    return n * spec.flops_per_point()
