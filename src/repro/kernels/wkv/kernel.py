"""Pallas WKV kernel: RWKV-6 recurrence with VMEM-resident state.

The SPerf-B analysis showed the chunked WKV's inter-chunk state
(B, nh, hd, hd -- 268 MB/device on rwkv6-7b) streaming through HBM once per
chunk dominates the memory term. This kernel is the Occamy answer: the state
lives in VMEM *scratch* across the chunk grid dimension (the SPM-resident
accumulator), so HBM traffic reduces to the r/k/v/w chunk streams + y writes.

Grid: (B, nh, n_chunks) with the chunk dim innermost; scratch S (hd, hd) f32
persists across chunk steps of one (b, h) pair (same discipline as the flash
kernel's m/l/acc). Math identical to models.rwkv6.wkv_chunked incl. the
mid-chunk exponent rescale.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *,
                chunk: int, hd: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0, 0].astype(jnp.float32)          # (Q, hd)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)          # log-decay, <= 0
    u = u_ref[0].astype(jnp.float32)             # (hd,)
    S = s_ref[...]                               # (hd, hd) carried state

    cum = jnp.cumsum(w, axis=0)
    # intra-chunk (mid-rescaled, see models/rwkv6.py)
    ri = r * jnp.exp(cum - w)
    mid = cum[chunk // 2][None, :]
    ri_s = r * jnp.exp(cum - w - mid)
    kj_s = k * jnp.exp(mid - cum)
    att = jax.lax.dot_general(ri_s, kj_s, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (Q, Q)
    mask = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
            > jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    att = jnp.where(mask, att, 0.0)
    y = jax.lax.dot(att, v, preferred_element_type=jnp.float32)
    y += jnp.sum(r * u[None, :] * k, axis=1, keepdims=True) * v  # diag bonus
    y += jax.lax.dot(ri, S, preferred_element_type=jnp.float32)  # inter-chunk

    # state update: S' = diag(exp(cum_Q)) S + sum_j exp(cum_Q - cum_j) k_j v_j^T
    decay_out = jnp.exp(cum[-1][None, :] - cum)                  # (Q, hd)
    s_ref[...] = S * jnp.exp(cum[-1])[:, None] + jax.lax.dot_general(
        k * decay_out, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[0, 0] = y.astype(o_ref.dtype)


def wkv_pallas(r, k, v, w_log, u, *, chunk: int = 128,
               interpret: bool = False):
    """r/k/v/w_log: (B, T, nh, hd) with T % chunk == 0 (ops.py pads);
    u: (nh, hd). Returns y (B, T, nh, hd) f32."""
    B, T, nh, hd = r.shape
    assert T % chunk == 0
    nc = T // chunk
    # layout: (B, nh, nc*chunk, hd) so chunk blocks are contiguous
    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(B, nh, T, hd)
    rb, kb, vb, wb = map(to_bh, (r, k, v, w_log))
    kern = functools.partial(_wkv_kernel, chunk=chunk, hd=hd)
    out = pl.pallas_call(
        kern,
        grid=(B, nh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, hd), lambda b, h, c: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nh, T, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rb, kb, vb, wb, u)
    return out.reshape(B, nh, nc * chunk, hd).transpose(0, 2, 1, 3)
