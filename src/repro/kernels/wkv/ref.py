"""Oracle for the WKV kernel: the sequential recurrence (models.rwkv6)."""
from __future__ import annotations

from repro.models.rwkv6 import rwkv_scan_ref


def wkv_ref(r, k, v, w_log, u):
    y, _ = rwkv_scan_ref(r, k, v, w_log, u)
    return y
