"""Public WKV-kernel API: padding + dispatch.

The chunk length defaults to the autotune table (``repro.kernels.tuning``,
op ``"wkv"``) instead of a hardcoded constant; pass ``chunk=`` to override.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import tuning
from repro.kernels.wkv.kernel import wkv_pallas


def wkv(r, k, v, w_log, u, *, chunk: int | None = None,
        interpret: bool = False):
    """Pads T to a chunk multiple and runs the Pallas WKV kernel.

    ``chunk=None`` (default) consults the autotune table for the dtype --
    eagerly, outside the jitted body, so a later ``tuning.register`` is
    honored instead of being baked into a compiled program."""
    if chunk is None:
        chunk = tuning.wkv_chunk(r.shape[1], r.dtype)
    return _wkv_jit(r, k, v, w_log, u, chunk=int(chunk), interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _wkv_jit(r, k, v, w_log, u, *, chunk: int, interpret: bool):
    B, T, nh, hd = r.shape
    chunk = min(chunk, max(8, T))
    pad = (-T) % chunk
    if pad:
        zp = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, zp), jnp.pad(k, zp), jnp.pad(v, zp)
        w_log = jnp.pad(w_log, zp)
    y = wkv_pallas(r, k, v, w_log, u, chunk=chunk, interpret=interpret)
    return y[:, :T]


def flops(B, T, nh, hd, chunk=128) -> int:
    """Dots only: intra-chunk (2 x Q^2 x hd x 2) + inter-chunk (2 x Q x hd^2)
    + state update (2 x Q x hd^2), per (b, h, c)."""
    nc = -(-T // chunk)
    per = 4 * chunk * chunk * hd + 4 * chunk * hd * hd
    return B * nh * nc * per
