"""Public WKV-kernel API: padding + dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.wkv.kernel import wkv_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv(r, k, v, w_log, u, *, chunk: int = 128, interpret: bool = False):
    """Pads T to a chunk multiple and runs the Pallas WKV kernel."""
    B, T, nh, hd = r.shape
    chunk = min(chunk, max(8, T))
    pad = (-T) % chunk
    if pad:
        zp = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, zp), jnp.pad(k, zp), jnp.pad(v, zp)
        w_log = jnp.pad(w_log, zp)
    y = wkv_pallas(r, k, v, w_log, u, chunk=chunk, interpret=interpret)
    return y[:, :T]


def flops(B, T, nh, hd, chunk=128) -> int:
    """Dots only: intra-chunk (2 x Q^2 x hd x 2) + inter-chunk (2 x Q x hd^2)
    + state update (2 x Q x hd^2), per (b, h, c)."""
    nc = -(-T // chunk)
    per = 4 * chunk * chunk * hd + 4 * chunk * hd * hd
    return B * nh * nc * per
