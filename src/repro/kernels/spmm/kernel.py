"""Pallas BCSR x dense SpMM: the SU-indirection kernel (paper Fig. 5 / 6b).

Occamy mechanism: an SU streams the sparse row's column indices; a second SU
uses them as an *indirect* stream into the dense operand, so the FPU executes
back-to-back FMAs. TPU translation: the block-column index stream is *scalar
prefetched* and drives the BlockSpec ``index_map`` of the dense operand -- the
index stream literally steers the DMA engine one tile ahead of compute
(``PrefetchScalarGridSpec``), while the MXU consumes (bm x bk) x (bk x bn)
tiles back-to-back.

Output residency (``nt``): the accumulator block is ``nt`` N-tiles wide --
(bm, nt*bn) resident in VMEM -- and the grid walks the nonzero-block stream
once per ``nt`` output tiles instead of once per tile.  The grid is
(N / (nt*bn), nnzb, nt) with the sub-tile dim innermost: the A-block spec's
index map is constant across the ``t`` steps, so the Pallas pipeline fetches
each stream block ONCE per ``i`` while the dense operand keeps streaming one
(bk, bn) K-tile per step (double-buffered by the pipeline, steered by the
scalar-prefetched column index).  Stream re-reads drop from ``N/bn`` to
``N/(nt*bn)`` -- Occamy's SPM-resident accumulation widened across the
output row.

Output revisiting: the block stream is sorted by block-row, so for a fixed
N-supertile the output block index is non-decreasing across the inner grid
dims; Pallas keeps the accumulator tile resident in VMEM until the row
changes (first-visit zeroing via ``pl.when``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import streamwalk


def _spmm_kernel(brows_ref, bcols_ref, blocks_ref, b_ref, o_ref, *,
                 bn: int, nt: int, scales_ref=None):
    i = pl.program_id(1)  # position in the nonzero-block stream
    t = pl.program_id(2)  # which resident N-subtile this step accumulates

    @pl.when(streamwalk.row_start(brows_ref, i) & (t == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = blocks_ref[0]          # (bm, bk)
    if scales_ref is not None:
        # BlockQuant dequant: one scale multiply per stream block, computed
        # as ``values.astype(f32) * scale`` -- verbatim the host dequantize
        # contract, so the narrow path is bit-identical to dequantizing on
        # host and running the f32 kernel.
        a = a.astype(jnp.float32) * scales_ref[0, 0]
    b = b_ref[...]             # (bk, bn)
    acc = jnp.dot(a, b, preferred_element_type=jnp.float32).astype(o_ref.dtype)
    if nt == 1:
        o_ref[...] += acc
    else:
        # static unroll over the resident sub-tiles: exactly one branch fires
        # per step, each with a static (lane-aligned) store offset.
        for tt in range(nt):
            @pl.when(t == tt)
            def _acc(tt=tt):
                o_ref[:, tt * bn:(tt + 1) * bn] += acc


def _spmm_quant_kernel(brows_ref, bcols_ref, blocks_ref, scales_ref, b_ref,
                       o_ref, *, bn: int, nt: int):
    _spmm_kernel(brows_ref, bcols_ref, blocks_ref, b_ref, o_ref,
                 bn=bn, nt=nt, scales_ref=scales_ref)


def spmm_bcsr(block_rows: jax.Array, block_cols: jax.Array, blocks: jax.Array,
              dense: jax.Array, *, n_block_rows: int, bn: int = 128,
              nt: int = 1, out_dtype=jnp.float32,
              interpret: bool = False,
              scales: jax.Array | None = None) -> jax.Array:
    """C = A @ dense where A is streamed as flattened BCSR blocks.

    Args:
      block_rows / block_cols: (nnzb,) int32, sorted by (row, col); every
        block-row must appear at least once (ops.py pads empty rows).
      blocks: (nnzb, bm, bk).
      dense: (K, N) with K = n_block_cols * bk, N % (nt * bn) == 0.
      n_block_rows: number of block rows of A (static).
      nt: output-residency width -- how many (bm, bn) N-tiles of the output
        row stay VMEM-resident per stream walk (1 = the classic kernel).
      scales: (nnzb,) or (nnzb, 1) f32 per-block dequant scales for narrow
        (fp8/int8) ``blocks`` (BlockQuant); None keeps the wide path
        byte-identical to the pre-quant kernel.
    Returns:
      (n_block_rows * bm, N) in ``out_dtype``.
    """
    nnzb, bm, bk = blocks.shape
    K, N = dense.shape
    assert nt >= 1, nt
    assert N % (nt * bn) == 0, (N, bn, nt)
    # j outer (N-supertile), i middle (stream walk), t inner (resident
    # sub-tile): per-row accumulation stays contiguous, and the A-block index
    # map is constant in t so each stream block is DMA'd once per i.
    walk = streamwalk.StreamWalk(outer=1, inner=1)
    grid = walk.grid((N // (nt * bn),), nnzb, (nt,))

    in_specs = [
        # A-block stream: affine walk of the flattened block array;
        # constant across t -> one fetch per stream position.
        walk.stream_spec((1, bm, bk)),
        # Dense operand: the *indirect* stream -- block-col index
        # steers which K-tile the DMA fetches (SU indirection); the
        # pipeline double-buffers the next (bk, bn) tile while the
        # MXU consumes the current one.
        walk.indexed_spec((bk, bn), lambda o, col, t: (col, o[0] * nt + t[0])),
    ]
    operands = [block_rows, block_cols, blocks, dense]
    if scales is None:
        kern = functools.partial(_spmm_kernel, bn=bn, nt=nt)
    else:
        # Scale stream rides the same affine walk as the A blocks (one
        # (1, 1) scalar per stream position, constant across t).
        kern = functools.partial(_spmm_quant_kernel, bn=bn, nt=nt)
        in_specs.insert(1, walk.stream_spec((1, 1)))
        operands.insert(3, scales.reshape(nnzb, 1).astype(jnp.float32))
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # block_rows, block_cols
            grid=grid,
            in_specs=in_specs,
            out_specs=walk.row_spec((bm, nt * bn), lambda o, row, t: (row, o[0])),
        ),
        out_shape=jax.ShapeDtypeStruct((n_block_rows * bm, N), out_dtype),
        interpret=interpret,
    )(*operands)


def stream_walks(n: int, bn: int, nt: int) -> int:
    """How many times one call re-walks the index/block stream: the reread
    factor ``ceil(N / (nt*bn))`` (1 == the whole stream is read once)."""
    return -(-n // (nt * bn))
