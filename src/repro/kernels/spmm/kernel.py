"""Pallas BCSR x dense SpMM: the SU-indirection kernel (paper Fig. 5 / 6b).

Occamy mechanism: an SU streams the sparse row's column indices; a second SU
uses them as an *indirect* stream into the dense operand, so the FPU executes
back-to-back FMAs. TPU translation: the block-column index stream is *scalar
prefetched* and drives the BlockSpec ``index_map`` of the dense operand -- the
index stream literally steers the DMA engine one tile ahead of compute
(``PrefetchScalarGridSpec``), while the MXU consumes (bm x bk) x (bk x bn)
tiles back-to-back.

Output revisiting: the block stream is sorted by block-row, so for a fixed
N-tile the output block index is non-decreasing across the inner grid dim;
Pallas keeps the accumulator tile resident in VMEM until the row changes
(first-visit zeroing via ``pl.when``), mirroring Occamy's SPM-resident
accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _spmm_kernel(brows_ref, bcols_ref, blocks_ref, b_ref, o_ref):
    i = pl.program_id(1)  # position in the nonzero-block stream (inner dim)
    row = brows_ref[i]
    prev = brows_ref[jnp.maximum(i - 1, 0)]

    @pl.when((i == 0) | (row != prev))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = blocks_ref[0]          # (bm, bk)
    b = b_ref[...]             # (bk, bn)
    o_ref[...] += jnp.dot(
        a, b, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def spmm_bcsr(block_rows: jax.Array, block_cols: jax.Array, blocks: jax.Array,
              dense: jax.Array, *, n_block_rows: int, bn: int = 128,
              out_dtype=jnp.float32, interpret: bool = False) -> jax.Array:
    """C = A @ dense where A is streamed as flattened BCSR blocks.

    Args:
      block_rows / block_cols: (nnzb,) int32, sorted by (row, col); every
        block-row must appear at least once (ops.py pads empty rows).
      blocks: (nnzb, bm, bk).
      dense: (K, N) with K = n_block_cols * bk, N % bn == 0.
      n_block_rows: number of block rows of A (static).
    Returns:
      (n_block_rows * bm, N) in ``out_dtype``.
    """
    nnzb, bm, bk = blocks.shape
    K, N = dense.shape
    assert N % bn == 0, (N, bn)
    grid = (N // bn, nnzb)  # j outer, i inner: per-row accumulation contiguity

    return pl.pallas_call(
        _spmm_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # block_rows, block_cols
            grid=grid,
            in_specs=[
                # A-block stream: affine walk of the flattened block array.
                pl.BlockSpec((1, bm, bk), lambda j, i, rows, cols: (i, 0, 0)),
                # Dense operand: the *indirect* stream -- block-col index
                # steers which K-tile the DMA fetches (SU indirection).
                pl.BlockSpec((bk, bn), lambda j, i, rows, cols: (cols[i], j)),
            ],
            out_specs=pl.BlockSpec(
                (bm, bn), lambda j, i, rows, cols: (rows[i], j)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_block_rows * bm, N), out_dtype),
        interpret=interpret,
    )(block_rows, block_cols, blocks, dense)
