"""Public SpMM API: BCSR container in, padded/normalized kernel call out.

Two entry points:
  * :func:`spmm`         -- single (M, K) BCSR x (K, N) dense.
  * :func:`spmm_batched` -- BatchedBCSR (shared index stream, per-batch
    blocks) x (B, K, N) [or a broadcast (K, N)] dense, via ``vmap`` of the
    same Pallas kernel; the index stream is replicated across the batch
    exactly like Occamy replicates it across clusters.

Tile selection defaults to the autotune table in ``repro.kernels.tuning``
(pass ``bn=`` explicitly to override).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import BCSR, BatchedBCSR
from repro.kernels import tuning
from repro.kernels.spmm.kernel import spmm_bcsr


def pad_empty_rows(a: BCSR | BatchedBCSR):
    """Ensure every block-row appears in the stream (kernel requirement:
    unvisited output tiles are undefined). Adds one zero block at col 0 for
    each empty row; stream stays (row, col)-sorted. Host-side (numpy).

    Works for both the single and the batched container (the batched one
    shares a single index stream, so the same rows are padded for every
    batch element)."""
    gm = a.grid_shape[0]
    rows = np.asarray(a.block_rows)
    present = np.zeros(gm, bool)
    present[rows] = True
    missing = np.nonzero(~present)[0].astype(np.int32)
    if missing.size == 0:
        return a  # common case: no D2H transfer of the block values
    cols = np.asarray(a.block_cols)
    blocks = np.asarray(a.blocks)
    bm, bk = a.block
    rows = np.concatenate([rows, missing])
    cols = np.concatenate([cols, np.zeros_like(missing)])
    zshape = ((missing.size, bm, bk) if isinstance(a, BCSR)
              else (blocks.shape[0], missing.size, bm, bk))
    blocks = np.concatenate([blocks, np.zeros(zshape, blocks.dtype)],
                            axis=0 if isinstance(a, BCSR) else 1)
    order = np.lexsort((cols, rows))
    indptr = np.zeros(gm + 1, np.int32)
    np.cumsum(np.bincount(rows, minlength=gm), out=indptr[1:])
    scales = None
    if a.scales is not None:
        # The scale stream rides the block stream: pad with 1.0 (zero
        # blocks dequantize to zero under any scale) and apply the same
        # (row, col) re-sort.
        s = np.asarray(a.scales, np.float32)
        pad1 = np.ones(((missing.size,) if isinstance(a, BCSR)
                        else (s.shape[0], missing.size)), np.float32)
        s = np.concatenate([s, pad1], axis=-1)
        scales = jnp.asarray(s[..., order])
    kw = dict(indptr=jnp.asarray(indptr),
              block_rows=jnp.asarray(rows[order]),
              block_cols=jnp.asarray(cols[order]),
              shape=a.shape, block=a.block, scales=scales)
    if isinstance(a, BCSR):
        return BCSR(blocks=jnp.asarray(blocks[order]), **kw)
    return BatchedBCSR(blocks=jnp.asarray(blocks[:, order]), **kw)


@functools.partial(jax.jit, static_argnames=("n_block_rows", "bn", "nt",
                                             "out_dtype", "interpret"))
def _spmm_jit(block_rows, block_cols, blocks, dense, scales=None, *,
              n_block_rows, bn, nt, out_dtype, interpret):
    return spmm_bcsr(block_rows, block_cols, blocks, dense,
                     n_block_rows=n_block_rows, bn=bn, nt=nt,
                     out_dtype=out_dtype, interpret=interpret, scales=scales)


@functools.partial(jax.jit, static_argnames=("n_block_rows", "bn", "nt",
                                             "out_dtype", "interpret"))
def _spmm_batched_jit(block_rows, block_cols, blocks, dense, scales=None, *,
                      n_block_rows, bn, nt, out_dtype, interpret):
    f = functools.partial(spmm_bcsr, n_block_rows=n_block_rows, bn=bn, nt=nt,
                          out_dtype=out_dtype, interpret=interpret)
    if scales is None:
        return jax.vmap(lambda bl, d: f(block_rows, block_cols, bl, d)
                        )(blocks, dense)
    return jax.vmap(lambda bl, s, d: f(block_rows, block_cols, bl, d, scales=s)
                    )(blocks, scales, dense)


def _resolve_bn(bn, n, dtype, bk) -> int:
    """Resolve the dense-operand N-tile.

    An explicit ``bn=`` is honored exactly -- it must be a positive multiple
    of the 128-lane width or this raises (the old behavior silently clamped
    via ``min(bn, max(128, n))``, so ``bn=100`` produced an unaligned tile
    and ``bn=256`` with small N was silently rewritten).  ``bn=None``
    consults the autotune table, which applies the shape/VMEM clamp."""
    if bn is not None:
        bn = int(bn)
        if bn < tuning.LANE or bn % tuning.LANE:
            raise ValueError(
                f"explicit bn={bn} is not a positive multiple of the "
                f"{tuning.LANE}-lane tile width; pass a {tuning.LANE}-aligned"
                " override or bn=None to use the autotune table")
        return bn
    return tuning.spmm_bn(n, dtype, bk=bk)


def _resolve_nt(nt, bn, n, dtype, bk) -> int:
    """Resolve the output-residency width (how many N-tiles of one output
    row stay VMEM-resident per stream walk).  An explicit ``nt`` must be a
    positive int -- honored exactly; ``nt=None`` consults the autotune table
    (shape/VMEM clamped)."""
    if nt is not None:
        nt = int(nt)
        if nt < 1:
            raise ValueError(f"nt={nt} must be >= 1")
        return nt
    # clamp the table's nt against the *resolved* bn (which may be an
    # explicit override, not the table's own)
    raw = int(tuning._row("spmm", dtype).get("nt", 1))
    return tuning._clamp_nt(raw, bn, n, dtype, bk)


def spmm(a: BCSR, dense: jax.Array, *, bn: int | None = None,
         nt: int | None = None, out_dtype=jnp.float32,
         interpret: bool = False) -> jax.Array:
    """C = A @ dense. Pads N to a multiple of ``nt * bn`` and strips after.

    ``bn=None`` / ``nt=None`` (default) consult the autotune table for the
    dtype/shape; ``nt`` is the output-residency width (the index/block
    stream is re-walked ``ceil(N / (nt*bn))`` times)."""
    a = pad_empty_rows(a)
    K, N = dense.shape
    assert K == a.shape[1], (a.shape, dense.shape)
    # Quantized streams key the tile table on the *narrow* block dtype
    # (1-byte bucket rows: wider tiles for the same VMEM footprint).
    tile_dtype = a.blocks.dtype if a.scales is not None else dense.dtype
    bn = _resolve_bn(bn, N, tile_dtype, a.block[1])
    nt = _resolve_nt(nt, bn, N, tile_dtype, a.block[1])
    n_pad = (-N) % (nt * bn)
    if n_pad:
        dense = jnp.pad(dense, ((0, 0), (0, n_pad)))
    gm, _ = a.grid_shape
    out = _spmm_jit(a.block_rows, a.block_cols, a.blocks, dense, a.scales,
                    n_block_rows=gm, bn=bn, nt=nt, out_dtype=out_dtype,
                    interpret=interpret)
    return out[:, :N] if n_pad else out


def spmm_batched(a: BatchedBCSR, dense: jax.Array, *, bn: int | None = None,
                 nt: int | None = None, out_dtype=jnp.float32,
                 interpret: bool = False) -> jax.Array:
    """C[b] = A[b] @ dense[b] for a shared-index-stream batch.

    ``dense`` is (B, K, N), or (K, N) to broadcast one dense operand across
    the batch (the MoE dispatch case: many sparse routings of one token
    block). Returns (B, M, N)."""
    a = pad_empty_rows(a)
    B = a.batch
    if dense.ndim == 2:
        dense = jnp.broadcast_to(dense, (B,) + dense.shape)
    assert dense.shape[0] == B and dense.shape[1] == a.shape[2], (
        a.shape, dense.shape)
    N = dense.shape[2]
    tile_dtype = a.blocks.dtype if a.scales is not None else dense.dtype
    bn = _resolve_bn(bn, N, tile_dtype, a.block[1])
    nt = _resolve_nt(nt, bn, N, tile_dtype, a.block[1])
    n_pad = (-N) % (nt * bn)
    if n_pad:
        dense = jnp.pad(dense, ((0, 0), (0, 0), (0, n_pad)))
    gm, _ = a.grid_shape
    out = _spmm_batched_jit(a.block_rows, a.block_cols, a.blocks, dense,
                            a.scales, n_block_rows=gm, bn=bn, nt=nt,
                            out_dtype=out_dtype, interpret=interpret)
    return out[..., :N] if n_pad else out


def flops(a: BCSR | BatchedBCSR, n: int) -> int:
    """Useful FLOPs: 2 * nnz_elements * N (paper counts nonzero FMAs).

    For a BatchedBCSR, union-pattern positions holding an all-zero tile in a
    given batch element are *stream* work but not useful FLOPs, so they are
    excluded (per-element nonzero-block count, not B * nnzb_union)."""
    bm, bk = a.block
    if isinstance(a, BatchedBCSR):
        nz_blocks = int(jnp.any(a.blocks != 0, axis=(2, 3)).sum())
        return 2 * nz_blocks * bm * bk * n
    return 2 * int(a.nnzb) * bm * bk * n
