"""Public SpMM API: BCSR container in, padded/normalized kernel call out."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import BCSR
from repro.kernels.spmm.kernel import spmm_bcsr


def pad_empty_rows(a: BCSR) -> BCSR:
    """Ensure every block-row appears in the stream (kernel requirement:
    unvisited output tiles are undefined). Adds one zero block at col 0 for
    each empty row; stream stays (row, col)-sorted. Host-side (numpy)."""
    gm, _ = a.grid_shape
    rows = np.asarray(a.block_rows)
    cols = np.asarray(a.block_cols)
    blocks = np.asarray(a.blocks)
    present = np.zeros(gm, bool)
    present[rows] = True
    missing = np.nonzero(~present)[0].astype(np.int32)
    if missing.size == 0:
        return a
    bm, bk = a.block
    rows = np.concatenate([rows, missing])
    cols = np.concatenate([cols, np.zeros_like(missing)])
    blocks = np.concatenate([blocks, np.zeros((missing.size, bm, bk), blocks.dtype)])
    order = np.lexsort((cols, rows))
    indptr = np.zeros(gm + 1, np.int32)
    np.cumsum(np.bincount(rows, minlength=gm), out=indptr[1:])
    return BCSR(indptr=jnp.asarray(indptr),
                block_rows=jnp.asarray(rows[order]),
                block_cols=jnp.asarray(cols[order]),
                blocks=jnp.asarray(blocks[order]),
                shape=a.shape, block=a.block)


@functools.partial(jax.jit, static_argnames=("n_block_rows", "bn", "out_dtype", "interpret"))
def _spmm_jit(block_rows, block_cols, blocks, dense, *, n_block_rows, bn,
              out_dtype, interpret):
    return spmm_bcsr(block_rows, block_cols, blocks, dense,
                     n_block_rows=n_block_rows, bn=bn, out_dtype=out_dtype,
                     interpret=interpret)


def spmm(a: BCSR, dense: jax.Array, *, bn: int = 128, out_dtype=jnp.float32,
         interpret: bool = False) -> jax.Array:
    """C = A @ dense. Pads N to a multiple of ``bn`` and strips it after."""
    a = pad_empty_rows(a)
    K, N = dense.shape
    assert K == a.shape[1], (a.shape, dense.shape)
    bn = min(bn, max(128, N))
    n_pad = (-N) % bn
    if n_pad:
        dense = jnp.pad(dense, ((0, 0), (0, n_pad)))
    gm, _ = a.grid_shape
    out = _spmm_jit(a.block_rows, a.block_cols, a.blocks, dense,
                    n_block_rows=gm, bn=bn, out_dtype=out_dtype,
                    interpret=interpret)
    return out[:, :N] if n_pad else out


def flops(a: BCSR, n: int) -> int:
    """Useful FLOPs: 2 * nnz_elements * N (paper counts nonzero FMAs)."""
    bm, bk = a.block
    return 2 * int(a.nnzb) * bm * bk * n
