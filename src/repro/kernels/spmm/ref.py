"""Pure-jnp oracles + no-SU baseline for SpMM."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import BCSR


def spmm_ref(a: BCSR, dense: jax.Array) -> jax.Array:
    """Oracle: densify and matmul in f32."""
    return jnp.matmul(a.todense().astype(jnp.float32),
                      dense.astype(jnp.float32))


def spmm_gather_baseline(a: BCSR, dense: jax.Array) -> jax.Array:
    """The *no-SU* baseline: explicit gather of dense K-tiles by index, then
    per-block matmul + segment-sum scatter into rows. Same math, but the
    gather/scatter traffic goes through generic XLA ops rather than the
    streaming kernel -- mirrors the paper's scalar-ISA baseline.
    """
    nnzb, bm, bk = a.blocks.shape
    K, N = dense.shape
    tiles = dense.reshape(K // bk, bk, N)
    gathered = jnp.take(tiles, a.block_cols, axis=0)          # (nnzb, bk, N)
    partial = jnp.einsum("zmk,zkn->zmn", a.blocks.astype(jnp.float32),
                         gathered.astype(jnp.float32))        # (nnzb, bm, N)
    gm = a.shape[0] // bm
    out = jnp.zeros((gm, bm, N), jnp.float32)
    out = out.at[a.block_rows].add(partial)
    return out.reshape(a.shape[0], N)
