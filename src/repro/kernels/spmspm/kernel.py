"""Pallas SpMSpM kernel: tiled index-stream intersection (paper Fig. 6c).

Occamy mechanism: two SUs merge-intersect the sorted index streams of a CSR
row of A and a CSC column of B; the FPU multiply-accumulates on matches, and
the paper scores the comparator array by *index comparison rate* (GCOMP/s).

TPU translation: merge loops are serial and hostile to the VPU, so the
comparator array is re-shaped into what the VPU does natively -- **broadcast
all-pairs comparison of index tiles**: one (rt x ct x Lb) vector `==` performs
rt*ct*Lb index comparisons per step. Rows of A (padded-ELL, sorted keys) meet
columns of B; matches gate a multiply-accumulate into a dense (rt x ct) output
tile resident in VMEM. GCOMP/s maps to VPU comparison throughput; utilization
is useful/issued comparisons (reported by ``ops.comparison_stats``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import INVALID_KEY


def _spmspm_kernel(ak_ref, av_ref, bk_ref, bv_ref, o_ref, *, rt, ct, la, lb,
                   as_ref=None):
    ak = ak_ref[...]                      # (rt, la) int32 sorted keys
    av = av_ref[...].astype(jnp.float32)  # (rt, la)
    if as_ref is not None:
        # BlockQuant dequant of the narrow A row stream: one f32 scale per
        # row, ``values.astype(f32) * scale`` -- verbatim the host
        # dequantize_rows contract, so narrow A values are bit-identical to
        # dequantizing on host and running the f32 kernel.
        av = av * as_ref[...]             # (rt, la) * (rt, 1)
    bk = bk_ref[...]                      # (ct, lb)
    bv = bv_ref[...].astype(jnp.float32)  # (ct, lb)

    def body(p, acc):
        # Comparator array step: keys of A at stream position p vs all of B.
        a_key = jax.lax.dynamic_slice(ak, (0, p), (rt, 1))      # (rt, 1)
        a_val = jax.lax.dynamic_slice(av, (0, p), (rt, 1))      # (rt, 1)
        eq = (a_key[:, None, :] == bk[None, :, :])              # (rt, ct, lb)
        eq &= a_key[:, None, :] != INVALID_KEY
        contrib = jnp.where(eq, a_val[:, None, :] * bv[None, :, :], 0.0)
        return acc + contrib.sum(axis=-1)                       # (rt, ct)

    acc = jax.lax.fori_loop(0, la, body, jnp.zeros((rt, ct), jnp.float32))
    o_ref[...] = acc.astype(o_ref.dtype)


def _spmspm_quant_kernel(ak_ref, av_ref, as_ref, bk_ref, bv_ref, o_ref, *,
                         rt, ct, la, lb):
    _spmspm_kernel(ak_ref, av_ref, bk_ref, bv_ref, o_ref,
                   rt=rt, ct=ct, la=la, lb=lb, as_ref=as_ref)


def spmspm_ell(a_keys: jax.Array, a_vals: jax.Array,
               b_keys: jax.Array, b_vals: jax.Array, *,
               rt: int = 8, ct: int = 8, nt: int = 1, out_dtype=jnp.float32,
               interpret: bool = False,
               a_scales: jax.Array | None = None) -> jax.Array:
    """C[r, c] = sum over key matches of A-row r and B-col c.

    a_keys/a_vals: (R, La) padded-ELL rows of A (keys ascending, INVALID pad).
    b_keys/b_vals: (C, Lb) padded-ELL *columns* of B.
    a_scales: (R,) or (R, 1) f32 per-row dequant scales for narrow (fp8/int8)
    ``a_vals`` (BlockQuant over the row stream); None keeps the wide path
    byte-identical to the pre-quant kernel.
    ``nt``: output-column residency -- one grid step holds an (rt, nt*ct)
    output tile resident and intersects against an (nt*ct, lb) B-stream
    block, so the A row stream (the serial ``la`` walk) runs once per ``nt``
    column tiles instead of once per tile.  Match accumulation per output
    element is unchanged (the ``la`` fori order), so any ``nt`` is
    bit-identical to ``nt=1``.
    Returns dense C (R, C); ``ops.py`` compacts to a sparse stream (the third
    SU's joint-index write-back).
    """
    R, la = a_keys.shape
    C, lb = b_keys.shape
    assert nt >= 1, nt
    wct = nt * ct
    assert R % rt == 0 and C % wct == 0, ((R, C), (rt, ct, nt))
    in_specs = [
        pl.BlockSpec((rt, la), lambda i, j: (i, 0)),
        pl.BlockSpec((rt, la), lambda i, j: (i, 0)),
        pl.BlockSpec((wct, lb), lambda i, j: (j, 0)),
        pl.BlockSpec((wct, lb), lambda i, j: (j, 0)),
    ]
    operands = [a_keys, a_vals, b_keys, b_vals]
    if a_scales is None:
        kern = functools.partial(_spmspm_kernel, rt=rt, ct=wct, la=la, lb=lb)
    else:
        kern = functools.partial(_spmspm_quant_kernel, rt=rt, ct=wct,
                                 la=la, lb=lb)
        in_specs.insert(2, pl.BlockSpec((rt, 1), lambda i, j: (i, 0)))
        operands.insert(2, a_scales.reshape(R, 1).astype(jnp.float32))
    return pl.pallas_call(
        kern,
        grid=(R // rt, C // wct),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((rt, wct), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, C), out_dtype),
        interpret=interpret,
    )(*operands)
