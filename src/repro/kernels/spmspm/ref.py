"""Pure-jnp oracle + no-SU baseline for SpMSpM."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import INVALID_KEY


def ell_to_dense(keys: np.ndarray, vals: np.ndarray, width: int) -> np.ndarray:
    """(R, L) padded-ELL streams -> dense (R, width)."""
    keys = np.asarray(keys)
    vals = np.asarray(vals)
    out = np.zeros((keys.shape[0], width), np.float32)
    for r in range(keys.shape[0]):
        m = keys[r] != INVALID_KEY
        out[r, keys[r][m]] += vals[r][m]
    return out


def spmspm_ref(a_keys, a_vals, b_keys, b_vals, inner: int) -> jax.Array:
    """Oracle: densify both streams and matmul (A rows x B cols over
    ``inner``-dim keys)."""
    da = ell_to_dense(a_keys, a_vals, inner)
    db = ell_to_dense(b_keys, b_vals, inner)
    return jnp.asarray(da @ db.T)


def spmspm_gather_baseline(a_keys, a_vals, b_keys, b_vals) -> jax.Array:
    """No-SU baseline: same all-pairs math via XLA ops (no VMEM tiling), i.e.
    the comparator runs in generic vector code -- the scalar-ISA analogue."""
    ak = jnp.asarray(a_keys)[:, None, :, None]   # (R, 1, La, 1)
    bk = jnp.asarray(b_keys)[None, :, None, :]   # (1, C, 1, Lb)
    av = jnp.asarray(a_vals)[:, None, :, None].astype(jnp.float32)
    bv = jnp.asarray(b_vals)[None, :, None, :].astype(jnp.float32)
    eq = (ak == bk) & (ak != INVALID_KEY)
    return jnp.where(eq, av * bv, 0.0).sum(axis=(2, 3))
