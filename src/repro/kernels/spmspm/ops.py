"""Public SpMSpM API: CSR/CSC streams in, dense or compacted-sparse out."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import INVALID_KEY
from repro.kernels import tuning
from repro.kernels.spmspm.kernel import spmspm_ell


def dense_to_ell_rows(dense: np.ndarray, width: int | None = None
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """Dense matrix -> padded-ELL (keys, vals) row streams (host-side)."""
    dense = np.asarray(dense)
    R, _ = dense.shape
    nnz_per_row = (dense != 0).sum(axis=1)
    width = int(width or max(1, nnz_per_row.max()))
    keys = np.full((R, width), INVALID_KEY, np.int32)
    vals = np.zeros((R, width), dense.dtype)
    for r in range(R):
        cols = np.nonzero(dense[r])[0]
        assert len(cols) <= width, (r, len(cols), width)
        keys[r, : len(cols)] = cols
        vals[r, : len(cols)] = dense[r, cols]
    return keys, vals


def dense_to_ell_cols(dense: np.ndarray, width: int | None = None):
    """Dense matrix -> padded-ELL *column* streams (CSC view)."""
    return dense_to_ell_rows(dense.T, width)


@functools.partial(jax.jit, static_argnames=("rt", "ct", "nt", "interpret"))
def _spmspm_jit(ak, av, bk, bv, a_scales=None, *, rt, ct, nt, interpret):
    return spmspm_ell(ak, av, bk, bv, rt=rt, ct=ct, nt=nt,
                      interpret=interpret, a_scales=a_scales)


def spmspm(a_keys, a_vals, b_keys, b_vals, *, rt: int | None = None,
           ct: int | None = None, nt: int | None = None,
           interpret: bool = False,
           a_scales: jax.Array | None = None) -> jax.Array:
    """Dense-result SpMSpM over padded-ELL streams; pads R/C to tiles.

    ``rt``/``ct``/``nt`` default to the autotune table
    (repro.kernels.tuning); ``nt`` is the output-column residency width (the
    A row stream is walked once per ``nt`` column tiles).  ``a_scales``
    carries per-row BlockQuant scales when ``a_vals`` is narrow (fp8/int8)
    -- the narrow dtype keys the 1-byte tile-table rows via ``av.dtype``."""
    ak, av = jnp.asarray(a_keys), jnp.asarray(a_vals)
    bk, bv = jnp.asarray(b_keys), jnp.asarray(b_vals)
    R, C = ak.shape[0], bk.shape[0]
    if rt is None or ct is None:
        trt, tct = tuning.spmspm_tiles(R, C, ak.shape[1], bk.shape[1],
                                       av.dtype)
        rt, ct = rt or trt, ct or tct
    if nt is None:
        nt = tuning.spmspm_nt(C, ct, bk.shape[1], av.dtype)
    elif int(nt) < 1:
        raise ValueError(f"nt={nt} must be >= 1")
    nt = int(nt)
    rp, cp = (-R) % rt, (-C) % (nt * ct)
    if a_scales is not None:
        a_scales = jnp.asarray(a_scales, jnp.float32).reshape(R, 1)
    if rp:
        ak = jnp.pad(ak, ((0, rp), (0, 0)), constant_values=INVALID_KEY)
        av = jnp.pad(av, ((0, rp), (0, 0)))
        if a_scales is not None:
            # Pad rows are INVALID-keyed (contribute nothing); scale 1.0
            # keeps the all-zero-row convention of quantize_rows.
            a_scales = jnp.pad(a_scales, ((0, rp), (0, 0)),
                               constant_values=1.0)
    if cp:
        bk = jnp.pad(bk, ((0, cp), (0, 0)), constant_values=INVALID_KEY)
        bv = jnp.pad(bv, ((0, cp), (0, 0)))
    out = _spmspm_jit(ak, av, bk, bv, a_scales, rt=rt, ct=ct, nt=nt,
                      interpret=interpret)
    return out[:R, :C]


def comparison_stats(a_keys, b_keys) -> dict:
    """Figure-of-merit accounting (paper Fig. 6c): issued vs useful index
    comparisons. Issued = R*C*La*Lb (the all-pairs tile sweep); useful =
    number of true key matches; utilization = useful/issued is the analogue
    of the paper's comparator utilization (<=49% on Occamy)."""
    ak, bk = np.asarray(a_keys), np.asarray(b_keys)
    issued = ak.shape[0] * bk.shape[0] * ak.shape[1] * bk.shape[1]
    b_valid = bk[bk != INVALID_KEY]
    useful = 0
    for r in range(ak.shape[0]):
        row = ak[r][ak[r] != INVALID_KEY]
        useful += int(np.isin(row, b_valid).sum())
    return {"issued": int(issued), "useful_upper": int(useful),
            "valid_a": int((ak != INVALID_KEY).sum()),
            "valid_b": int((bk != INVALID_KEY).sum())}


def compact_result(dense_c: jax.Array, capacity: int):
    """Third-SU write-back: dense result tile -> sorted (keys, values, count)
    joint-index stream."""
    R, C = dense_c.shape
    flat = dense_c.reshape(-1)
    nz = flat != 0
    keys = jnp.where(nz, jnp.arange(R * C, dtype=jnp.int32), INVALID_KEY)
    order = jnp.argsort(keys)[:capacity]
    out_keys = keys[order]
    out_vals = jnp.where(out_keys != INVALID_KEY, flat[order], 0)
    count = (out_keys != INVALID_KEY).sum().astype(jnp.int32)
    return out_keys, out_vals, count
