"""Shared BCSR stream-walk grid/BlockSpec construction (the SU discipline).

Both sparse clients -- ``spmm_bcsr`` (MoE dispatch) and
``flash_attention_sparse`` (block-sparse attention) -- walk a scalar-prefetched
sorted block-index stream with one grid dimension, keep an accumulator
VMEM-resident across each block-row's run of stream entries, and let the
Pallas pipeline double-buffer the next indexed tile while compute consumes
the current one.  This module is that shape, factored once:

* the grid layout ``(*outer, nnzb, *inner)`` with the stream walk at a fixed
  axis,
* the three BlockSpec families every stream client needs --
  ``stream_spec`` (affine walk of the flattened block array),
  ``indexed_spec`` (SU indirection: a prefetched index steers the DMA),
  ``row_spec`` (output revisiting keyed on the sorted row stream),
* the row-run predicates ``row_start`` / ``row_end`` that drive first-visit
  init and last-visit finalize of the resident accumulator.

Index-map convention (Pallas): maps receive ``(*grid_indices,
*scalar_prefetch_refs)``.  ``StreamWalk`` splits that argument list by its
declared geometry so client ``coords`` callbacks only see
``(outer_indices, index_value, inner_indices)``.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax.experimental import pallas as pl


class StreamWalk:
    """Grid/BlockSpec builder for a sorted block-index stream walk.

    Args:
      outer: number of grid dims before the stream axis (e.g. spmm's
        N-supertile ``j`` -> 1; attention's ``(b, h)`` -> 2).
      inner: number of grid dims after the stream axis (e.g. spmm's
        resident-subtile ``t`` -> 1).
    """

    def __init__(self, *, outer: int, inner: int = 0):
        assert outer >= 0 and inner >= 0
        self.outer = outer
        self.inner = inner

    def grid(self, outer_dims: tuple, nnzb: int, inner_dims: tuple = ()):
        assert len(outer_dims) == self.outer and len(inner_dims) == self.inner
        return (*outer_dims, nnzb, *inner_dims)

    def _split(self, args):
        n_grid = self.outer + 1 + self.inner
        grid, scalars = args[:n_grid], args[n_grid:]
        return (grid[:self.outer], grid[self.outer],
                grid[self.outer + 1:], scalars)

    def stream_spec(self, block_shape: tuple) -> pl.BlockSpec:
        """Affine walk of a flattened per-entry array: block ``i`` at stream
        position ``i``, constant across outer/inner dims (one fetch per
        stream position)."""
        def imap(*args):
            _, i, _, _ = self._split(args)
            return (i,) + (0,) * (len(block_shape) - 1)
        return pl.BlockSpec(block_shape, imap)

    def indexed_spec(self, block_shape: tuple, coords,
                     stream_arg: int = 1) -> pl.BlockSpec:
        """SU indirection: scalar-prefetch operand ``stream_arg`` (default:
        the column stream, by the (rows, cols, ...) prefetch convention) is
        read at the walk position and handed to ``coords(outer, value,
        inner)`` to steer the DMA."""
        def imap(*args):
            outer, i, inner, scalars = self._split(args)
            return coords(outer, scalars[stream_arg][i], inner)
        return pl.BlockSpec(block_shape, imap)

    def row_spec(self, block_shape: tuple, coords,
                 stream_arg: int = 0) -> pl.BlockSpec:
        """Output spec keyed on the sorted row stream: the block index is
        non-decreasing across the walk, so Pallas keeps the accumulator tile
        resident until the row changes."""
        return self.indexed_spec(block_shape, coords, stream_arg=stream_arg)


def row_start(rows_ref, i):
    """True at the first stream entry of each block-row run (drives the
    ``pl.when`` first-visit zeroing of the resident accumulator)."""
    prev = rows_ref[jnp.maximum(i - 1, 0)]
    return (i == 0) | (rows_ref[i] != prev)


def row_end(rows_ref, i, nnzb: int):
    """True at the last stream entry of each block-row run (drives the
    last-visit finalize/write-back)."""
    nxt = rows_ref[jnp.minimum(i + 1, nnzb - 1)]
    return (i == nnzb - 1) | (rows_ref[i] != nxt)
