"""Fault-tolerant training driver.

Contract (designed for 1000+ nodes, exercised here single-host):
* **Checkpoint/restart**: atomic checkpoints every ``ckpt_every`` steps; on
  (re)start the driver restores LATEST and resumes from the exact step --
  the data pipeline is step-addressable so no sample is lost or repeated.
* **Failure injection**: ``failure_hook(step)`` may raise ``SimulatedFailure``
  mid-run; ``run_with_restarts`` catches, re-constructs state from disk and
  continues -- the integration test kills training twice and checks the loss
  trajectory is identical to an uninterrupted run.
* **Straggler mitigation**: per-step deadline watchdog. Steps are dispatched
  async (JAX returns futures); if a step's completion exceeds
  ``straggler_factor`` x the trailing median, the event is logged and counted
  (at fleet scale the hook triggers re-scheduling / hot-spare swap; the
  decision logic is here, the actuation is deployment-specific).
* **Gradient compression**: optional top-k sparse gradient exchange
  (repro.grad_comp) toggles per-config.
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import SyntheticLM
from repro.models import model as M
from repro.optim.adamw import AdamW


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "checkpoints"
    keep: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10


class Trainer:
    def __init__(self, cfg, arch_cfg, train_step: Callable, optimizer: AdamW,
                 data: SyntheticLM, init_state: Callable,
                 failure_hook: Optional[Callable[[int], None]] = None,
                 shardings: Any = None):
        self.cfg = cfg
        self.arch_cfg = arch_cfg
        self.train_step = train_step
        self.optimizer = optimizer
        self.data = data
        self.init_state = init_state
        self.failure_hook = failure_hook
        self.shardings = shardings
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
        self.step_times: list = []
        self.straggler_events: list = []
        self.history: list = []

    # -------------------------------------------------------------- state --

    def _fresh_state(self):
        params = self.init_state()
        opt_state = self.optimizer.init(params)
        return {"params": params, "opt": opt_state}

    def _restore_or_init(self):
        latest = self.ckpt.latest_step()
        if latest is None:
            return self._fresh_state(), 0
        like = jax.eval_shape(self._fresh_state)
        state, step = self.ckpt.restore(like, shardings=self.shardings)
        return state, step + 1

    # ---------------------------------------------------------------- run --

    def run(self) -> dict:
        state, start = self._restore_or_init()
        for step in range(start, self.cfg.total_steps):
            if self.failure_hook:
                self.failure_hook(step)      # may raise SimulatedFailure
            batch = self.data.batch_at(step)
            t0 = time.monotonic()
            args = [state["params"], state["opt"], batch["tokens"]]
            if "embeddings" in batch:
                args.append(batch["embeddings"])
            params, opt, metrics = self.train_step(*args)
            loss = float(metrics["loss"])    # sync point = step completion
            dt = time.monotonic() - t0
            self._watch_stragglers(step, dt)
            state = {"params": params, "opt": opt}
            self.history.append((step, loss))
            if step % self.cfg.log_every == 0:
                print(f"step {step:5d} loss {loss:.4f} ({dt*1e3:.0f} ms)")
            if (step + 1) % self.cfg.ckpt_every == 0 or \
                    step == self.cfg.total_steps - 1:
                self.ckpt.save(step, state, metadata={"loss": loss})
        return {"state": state, "history": self.history,
                "stragglers": self.straggler_events}

    def _watch_stragglers(self, step: int, dt: float):
        self.step_times.append(dt)
        window = self.step_times[-32:]
        if len(window) >= 8:
            med = float(np.median(window[:-1]))
            if dt > self.cfg.straggler_factor * med:
                self.straggler_events.append(
                    {"step": step, "dt": dt, "median": med})


def run_with_restarts(make_trainer: Callable[[], Trainer],
                      max_restarts: int = 8) -> dict:
    """Supervisor loop: rebuild the trainer after each failure (fresh process
    state at fleet scale; here a fresh Trainer) and resume from LATEST."""
    restarts = 0
    while True:
        trainer = make_trainer()
        try:
            out = trainer.run()
            out["restarts"] = restarts
            return out
        except SimulatedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
