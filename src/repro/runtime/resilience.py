"""Serving resilience: deterministic fault injection, retry/shed policy,
health tracking, and the graceful-degradation ladder.

Occamy's system story is *latency tolerance* -- the fabric keeps computing
while individual transfers stall or straggle.  This module is the serving
translation of that discipline: every failure path in the two-phase serving
stack (``launch.serve``) is (a) injectable deterministically so it can be
tested and reproduced bit-for-bit, and (b) survivable per-request, so a
poisoned row never takes down its co-batched neighbours.

Pieces
------
* :class:`FaultSpec` / :class:`FaultPlan` -- a seeded registry of faults
  keyed by pipeline stage (``prefill / route / execute / attention /
  sample / quantize``).  Activation poisons (NaN/Inf) are injected with
  :func:`poison_rows` -- a single eager ``jnp.where`` on a host-built row
  mask, so injection adds **no host sync**; host-side faults raise
  :class:`InjectedFault`; stragglers sleep.  Every trigger is logged in
  ``plan.triggered`` so tests can assert exactly which faults fired.
* :class:`RetryPolicy` -- bounded exponential backoff for failed prefills
  and decode steps.
* :class:`HealthTracker` -- monotonic counters + a bounded event log,
  surfaced in ``summary()["health"]``.
* :class:`DegradationLadder` -- the ordered fallback rungs (quantized KV
  -> wide KV, sparse mask -> ``impl="ref"``, pipeline depth 1 -> 0) a
  driver walks down when health counters cross ``fail_threshold``.
* :func:`dequantize_cache` / :func:`corrupt_quant_scales` -- cache-level
  helpers for the ``kv_wide`` rung and the ``quantize``-stage fault.
"""
from __future__ import annotations

import dataclasses
import random as _random
import time
from collections import Counter
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import precision

STAGES: Tuple[str, ...] = (
    "prefill", "route", "execute", "attention", "sample", "quantize")

# Fault kinds: activation stages take nan/inf poisons plus host-side
# exception/straggler; the quantize stage corrupts cache scale leaves.
KINDS: Tuple[str, ...] = ("nan", "inf", "exception", "straggler")

_QUANT_LEAVES = frozenset({"k", "k_scale", "v", "v_scale"})


class InjectedFault(RuntimeError):
    """Raised by a FaultPlan ``exception`` fault (host-side failure)."""


class ShedError(RuntimeError):
    """Raised when admission control rejects a request (queue full)."""


def poison_rows(x: jax.Array, rows: Sequence[int], kind: str) -> jax.Array:
    """Overwrite batch rows of ``x`` with NaN or Inf, rows elsewhere intact.

    Built as one eager ``jnp.where`` on a host-constructed ``(B,)`` mask
    broadcast over trailing dims -- dispatched asynchronously, no sync.
    """
    if not rows:
        return x
    fill = {"nan": jnp.nan, "inf": jnp.inf}[kind]
    mask = jnp.zeros((x.shape[0],), jnp.bool_).at[jnp.asarray(list(rows))].set(True)
    mask = mask.reshape((-1,) + (1,) * (x.ndim - 1))
    return jnp.where(mask, jnp.asarray(fill, x.dtype), x)


def corrupt_quant_scales(cache: Any, rows: Sequence[int], kind: str) -> Any:
    """Poison the per-row ``k_scale``/``v_scale`` leaves of a quantized KV
    cache (batch axis 1: leaves are ``(layers, B, ...)``).  Non-quantized
    caches poison the wide ``k``/``v`` leaves instead so the fault is
    observable under every cache configuration."""
    if not rows:
        return cache

    def walk(node):
        if isinstance(node, dict):
            keys = set(node)
            if keys & {"k_scale", "v_scale"}:
                out = dict(node)
                for name in ("k_scale", "v_scale"):
                    if name in out:
                        out[name] = _poison_axis1(out[name], rows, kind)
                return out
            if keys & {"k", "v"} and keys <= _QUANT_LEAVES | {"occupancy"}:
                out = dict(node)
                for name in ("k", "v"):
                    if name in out:
                        out[name] = _poison_axis1(out[name], rows, kind)
                return out
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = type(node)
            return t(walk(v) for v in node)
        return node

    return walk(cache)


def _poison_axis1(x: jax.Array, rows: Sequence[int], kind: str) -> jax.Array:
    if x.ndim < 2 or not jnp.issubdtype(x.dtype, jnp.floating):
        return x
    fill = {"nan": jnp.nan, "inf": jnp.inf}[kind]
    mask = jnp.zeros((x.shape[1],), jnp.bool_).at[jnp.asarray(list(rows))].set(True)
    mask = mask.reshape((1, -1) + (1,) * (x.ndim - 2))
    return jnp.where(mask, jnp.asarray(fill, x.dtype), x)


def dequantize_cache(cache: Any, dtype=jnp.float32) -> Any:
    """Rewrite a quantized KV cache as a wide one: every ``{k, k_scale, v,
    v_scale}`` dict collapses to ``{k, v}`` dequantized to ``dtype`` (other
    leaves -- e.g. routing ``occupancy`` -- pass through untouched).  The
    ``kv_wide`` degradation rung: after this, decoding proceeds with
    ``kv_quant=None`` semantics on the same logical contents."""

    def walk(node):
        if isinstance(node, dict):
            if {"k", "k_scale", "v", "v_scale"} <= set(node):
                out = {k: v for k, v in node.items()
                       if k not in _QUANT_LEAVES}
                out["k"] = precision.dequantize_rows(
                    node["k"], node["k_scale"], dtype)
                out["v"] = precision.dequantize_rows(
                    node["v"], node["v_scale"], dtype)
                return out
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = type(node)
            return t(walk(v) for v in node)
        return node

    return walk(cache)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One deterministic fault: fires at ``stage`` when every non-None
    selector matches (``uid`` the request, ``row`` the batch row, ``step``
    the decode step counter, ``layer`` the per-step call index for stages
    hooked once per layer), at most ``times`` times total."""

    stage: str
    kind: str
    uid: Optional[int] = None
    row: Optional[int] = None
    step: Optional[int] = None
    layer: Optional[int] = None
    times: int = 1
    delay_s: float = 0.05

    def __post_init__(self):
        if self.stage not in STAGES:
            raise ValueError(f"stage must be one of {STAGES}, got {self.stage!r}")
        if self.kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}, got {self.kind!r}")
        if self.stage == "quantize" and self.kind in ("exception", "straggler"):
            raise ValueError("quantize faults corrupt scales: kind must be "
                             "'nan' or 'inf'")


class FaultPlan:
    """A deterministic, seeded registry of :class:`FaultSpec`\\ s.

    Drivers call :meth:`apply` at each stage boundary with the current
    activation and context; the plan either returns the activation
    untouched (no spec matches), returns it with matching rows poisoned,
    sleeps (straggler), or raises :class:`InjectedFault`.  ``triggered``
    logs every firing as ``(stage, kind, step, rows)`` so tests assert the
    exact fault set; :meth:`reset` re-arms all specs for an A/B re-run.
    """

    def __init__(self, specs: Sequence[FaultSpec] = ()):  # noqa: D401
        self.specs: List[FaultSpec] = list(specs)
        self.triggered: List[Tuple[str, str, Optional[int], Tuple[int, ...]]] = []
        self._remaining: Dict[int, int] = {
            i: s.times for i, s in enumerate(self.specs)}
        self._calls: Counter = Counter()

    # -- construction helpers ------------------------------------------------
    @classmethod
    def single(cls, stage: str, kind: str, **kw) -> "FaultPlan":
        return cls([FaultSpec(stage=stage, kind=kind, **kw)])

    @classmethod
    def random(cls, seed: int, uids: Sequence[int], rate: float, *,
               stages: Sequence[str] = ("prefill", "execute", "sample"),
               kinds: Sequence[str] = ("nan", "inf", "exception"),
               max_step: int = 8) -> "FaultPlan":
        """Seeded random plan: each uid independently faults with
        probability ``rate`` at a random (stage, kind, step)."""
        rng = _random.Random(seed)
        specs = []
        for uid in uids:
            if rng.random() >= rate:
                continue
            stage = rng.choice(list(stages))
            kind = rng.choice(list(kinds))
            step = None if stage == "prefill" else rng.randrange(max_step)
            specs.append(FaultSpec(stage=stage, kind=kind, uid=uid, step=step))
        return cls(specs)

    def reset(self) -> None:
        self.triggered = []
        self._remaining = {i: s.times for i, s in enumerate(self.specs)}
        self._calls = Counter()

    # -- matching ------------------------------------------------------------
    def _armed(self, stage: str, *, step: Optional[int],
               layer: Optional[int]) -> List[Tuple[int, FaultSpec]]:
        out = []
        for i, s in enumerate(self.specs):
            if s.stage != stage or self._remaining.get(i, 0) <= 0:
                continue
            if s.step is not None and s.step != step:
                continue
            if s.layer is not None and s.layer != layer:
                continue
            out.append((i, s))
        return out

    def _rows_for(self, spec: FaultSpec, uids: Optional[Sequence[Optional[int]]],
                  nrows: int) -> List[int]:
        if spec.row is not None:
            return [spec.row] if spec.row < nrows else []
        if spec.uid is not None:
            if uids is None:
                return []
            return [r for r, u in enumerate(uids) if u == spec.uid]
        return list(range(nrows))

    # -- application ---------------------------------------------------------
    def apply(self, stage: str, x: jax.Array, *, step: Optional[int] = None,
              uids: Optional[Sequence[Optional[int]]] = None) -> jax.Array:
        """Stage hook for batched activations ``x`` of shape ``(B, ...)``.

        Tracks a per-(stage, step) call counter so ``layer=`` selectors can
        target the Nth hook invocation within one step.
        """
        key = (stage, step)
        layer = self._calls[key]
        self._calls[key] += 1
        for i, spec in self._armed(stage, step=step, layer=layer):
            if spec.kind == "straggler":
                self._remaining[i] -= 1
                self.triggered.append((stage, "straggler", step, ()))
                time.sleep(spec.delay_s)
                continue
            if spec.kind == "exception":
                self._remaining[i] -= 1
                self.triggered.append((stage, "exception", step, ()))
                raise InjectedFault(
                    f"injected {stage} exception (step={step}, uid={spec.uid})")
            rows = self._rows_for(spec, uids, int(x.shape[0]))
            if not rows:
                continue
            self._remaining[i] -= 1
            self.triggered.append((stage, spec.kind, step, tuple(rows)))
            x = poison_rows(x, rows, spec.kind)
        return x

    def apply_cache(self, cache: Any, *, step: Optional[int] = None,
                    uids: Optional[Sequence[Optional[int]]] = None,
                    nrows: int = 0) -> Any:
        """Quantize-stage hook: corrupt cache scale leaves for matching rows."""
        layer = self._calls[("quantize", step)]
        self._calls[("quantize", step)] += 1
        for i, spec in self._armed("quantize", step=step, layer=layer):
            rows = self._rows_for(spec, uids, nrows)
            if not rows:
                continue
            self._remaining[i] -= 1
            self.triggered.append(("quantize", spec.kind, step, tuple(rows)))
            cache = corrupt_quant_scales(cache, rows, spec.kind)
        return cache


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff: attempt k (0-based retry index) sleeps
    ``min(base_delay_s * multiplier**k, max_delay_s)`` before re-running."""

    max_retries: int = 2
    base_delay_s: float = 0.0
    multiplier: float = 2.0
    max_delay_s: float = 1.0

    def delay(self, attempt: int) -> float:
        if self.base_delay_s <= 0:
            return 0.0
        return min(self.base_delay_s * self.multiplier ** attempt,
                   self.max_delay_s)

    def schedule(self) -> List[float]:
        return [self.delay(k) for k in range(self.max_retries)]


class HealthTracker:
    """Monotonic counters + a bounded event log for ``summary()['health']``."""

    MAX_EVENTS = 256

    def __init__(self):
        self.counters: Counter = Counter()
        self.events: List[Dict[str, Any]] = []

    def record(self, event: str, **detail) -> None:
        self.counters[event] += 1
        if len(self.events) < self.MAX_EVENTS:
            self.events.append({"event": event, **detail})

    def snapshot(self) -> Dict[str, Any]:
        return {"counters": dict(self.counters),
                "events": list(self.events)}


class DegradationLadder:
    """Ordered fallback rungs walked down as failures accumulate.

    Each :meth:`note_failure` increments a counter; every time it crosses a
    multiple of ``fail_threshold`` the next pending rung is returned for the
    driver to apply (``kv_wide`` -> dequantize the KV cache and decode wide,
    ``mask_ref`` -> rebuild the sparse attention spec with ``impl='ref'``,
    ``pipeline_serial`` -> drop StreamPipeline depth to 0).  Rungs that
    don't apply to the driver's configuration are skipped at construction.
    """

    RUNGS: Tuple[str, ...] = ("kv_wide", "mask_ref", "pipeline_serial")

    def __init__(self, rungs: Sequence[str], *, fail_threshold: int = 3):
        unknown = set(rungs) - set(self.RUNGS)
        if unknown:
            raise ValueError(f"unknown ladder rungs: {sorted(unknown)}")
        if fail_threshold < 1:
            raise ValueError("fail_threshold must be >= 1")
        self.pending: List[str] = [r for r in self.RUNGS if r in set(rungs)]
        self.applied: List[str] = []
        self.fail_threshold = int(fail_threshold)
        self.failures = 0

    @classmethod
    def for_serving(cls, *, kv_quant, attn_mask, pipeline_depth: int,
                    fail_threshold: int = 3) -> "DegradationLadder":
        rungs = []
        if kv_quant is not None:
            rungs.append("kv_wide")
        if attn_mask is not None and getattr(attn_mask, "impl", "ref") != "ref":
            rungs.append("mask_ref")
        if pipeline_depth > 0:
            rungs.append("pipeline_serial")
        return cls(rungs, fail_threshold=fail_threshold)

    def note_failure(self) -> Optional[str]:
        """Record one failure; return the next rung to apply when the
        running count crosses the threshold, else None."""
        self.failures += 1
        if self.pending and self.failures % self.fail_threshold == 0:
            rung = self.pending.pop(0)
            self.applied.append(rung)
            return rung
        return None

    def state(self) -> Dict[str, Any]:
        return {"failures": self.failures,
                "fail_threshold": self.fail_threshold,
                "applied": list(self.applied),
                "pending": list(self.pending)}
