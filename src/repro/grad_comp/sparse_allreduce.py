"""Top-k sparse gradient exchange built on the SU union op.

The distributed-optimization trick, expressed through the paper's technique:
each worker sparsifies its gradient to the top-k (index, value) stream
(`topk_sparsify`); combining two workers' streams is a *sorted-index union
with add-combine* -- exactly Occamy's SU merge mode (`union_add`). The
all-reduce becomes a butterfly of unions over log2(W) rounds, moving
O(k log W) elements instead of O(D); dropped mass stays in a local error-
feedback buffer (standard memory-compensated compression) so convergence is
preserved.

Two deployment paths:
* ``sparse_allreduce_tree``: pure-JAX reference over stacked worker streams
  (tests + single-process sim).
* ``sparse_psum_shard_map``: shard_map version where each data shard
  contributes its stream via ``jax.lax.all_gather`` of (idx, val) -- the
  collective moves only the compressed streams; the union runs locally.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.formats import INVALID_KEY
from repro.core.su import stream_densify, topk_sparsify, union_add


def compress(grad_flat: jax.Array, k: int,
             error: jax.Array | None = None) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k sparsify with error feedback. Returns (keys, vals, new_error)."""
    if error is not None:
        grad_flat = grad_flat + error
    keys, vals = topk_sparsify(grad_flat, k)
    dense_kept = stream_densify(keys, vals, jnp.asarray(k), grad_flat.shape[0])
    new_error = grad_flat - dense_kept
    return keys, vals, new_error


def union_reduce(keys_stack: jax.Array, vals_stack: jax.Array):
    """Union-combine W workers' sorted streams (tree reduction).

    keys_stack: (W, k) int32; vals_stack: (W, k). Returns a single
    (keys, vals, count) stream of capacity W*k.
    """
    W = keys_stack.shape[0]
    streams = [(keys_stack[i], vals_stack[i]) for i in range(W)]
    while len(streams) > 1:
        nxt = []
        for i in range(0, len(streams) - 1, 2):
            a, b = streams[i], streams[i + 1]
            u = union_add(a[0], a[1], b[0], b[1])
            nxt.append((u.keys, u.values))
        if len(streams) % 2:
            last = streams[-1]
            pad = last[0].shape[0]
            nxt.append((jnp.pad(last[0], (0, pad), constant_values=INVALID_KEY),
                        jnp.pad(last[1], (0, pad))))
        streams = nxt
    keys, vals = streams[0]
    count = (keys != INVALID_KEY).sum().astype(jnp.int32)
    return keys, vals, count


def sparse_allreduce_tree(grads_stack: jax.Array, k: int):
    """Reference: dense (W, D) grads -> mean gradient via sparse union.

    Returns (dense_mean (D,), per-worker error feedback (W, D))."""
    W, D = grads_stack.shape
    keys, vals, errs = jax.vmap(lambda g: compress(g, k))(grads_stack)
    ukeys, uvals, count = union_reduce(keys, vals)
    dense = stream_densify(ukeys, uvals, count, D) / W
    return dense, errs


def sparse_psum_shard_map(grad_local: jax.Array, k: int, axis: str):
    """Inside shard_map: exchange compressed streams over ``axis`` and
    union-reduce locally. grad_local: (D,) this shard's gradient."""
    keys, vals, _ = compress(grad_local, k)
    all_keys = jax.lax.all_gather(keys, axis)   # (W, k) -- the only traffic
    all_vals = jax.lax.all_gather(vals, axis)
    ukeys, uvals, count = union_reduce(all_keys, all_vals)
    W = all_keys.shape[0]
    return stream_densify(ukeys, uvals, count, grad_local.shape[0]) / W


def compression_ratio(D: int, k: int, workers: int) -> float:
    """Bytes moved vs dense ring all-reduce (2 x D per worker)."""
    dense = 2 * D * 4
    sparse = workers * k * 8  # int32 idx + f32 val gathered
    return dense / sparse
