"""Streaming-unit (SU) ops: indirection, intersection, union, joint-index write.

These are the paper's contribution (A) as composable JAX primitives. All ops
are shape-static (fixed capacity + explicit count) so they jit/pjit cleanly;
padding uses the sentinel ``INVALID_KEY``. The Pallas kernels in
``repro.kernels`` accelerate the hot paths; these functions are both the
reference semantics and the general-backend fallback.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.formats import INVALID_KEY


class IntersectResult(NamedTuple):
    keys: jax.Array    # (cap_a,) matched keys, INVALID-padded
    pos_a: jax.Array   # (cap_a,) positions in a of matches (cap_a past count)
    pos_b: jax.Array   # (cap_a,) positions in b of matches
    count: jax.Array   # () int32


class UnionResult(NamedTuple):
    keys: jax.Array    # (cap_a + cap_b,) union keys, INVALID-padded
    values: jax.Array  # (cap_a + cap_b,) add-combined values
    count: jax.Array   # () int32


def indirect_gather(data: jax.Array, indices: jax.Array) -> jax.Array:
    """SU indirection: stream ``data[indices[i]]``; indices int8/16/32 widen."""
    return jnp.take(data, indices.astype(jnp.int32), axis=0)


def indirect_scatter_add(out: jax.Array, indices: jax.Array, values: jax.Array) -> jax.Array:
    """SU indirect write-back with accumulate (sparse result assembly)."""
    return out.at[indices.astype(jnp.int32)].add(values)


def intersect(a_keys: jax.Array, b_keys: jax.Array) -> IntersectResult:
    """Sorted-stream intersection (the two index-capable SUs cooperating).

    Both inputs are ascending int32, INVALID-padded. Emits matched keys plus
    the *joint index stream* (positions into both operands) the third SU would
    write out in hardware.
    """
    cap_a = a_keys.shape[0]
    cap_b = b_keys.shape[0]
    # For each element of a, binary-search b (the comparator array in O(log)).
    loc = jnp.searchsorted(b_keys, a_keys)
    loc_c = jnp.minimum(loc, cap_b - 1).astype(jnp.int32)
    hit = (b_keys[loc_c] == a_keys) & (a_keys != INVALID_KEY)
    # Stable-compact the hit positions to the front.
    tagged = jnp.where(hit, jnp.arange(cap_a, dtype=jnp.int32), INVALID_KEY)
    pos_a = jnp.sort(tagged)
    pos_a_c = jnp.minimum(pos_a, cap_a - 1)
    count = hit.sum().astype(jnp.int32)
    valid = jnp.arange(cap_a) < count
    keys = jnp.where(valid, a_keys[pos_a_c], INVALID_KEY).astype(jnp.int32)
    pos_b = jnp.where(valid, loc_c[pos_a_c], cap_b).astype(jnp.int32)
    pos_a = jnp.where(valid, pos_a_c, cap_a).astype(jnp.int32)
    return IntersectResult(keys=keys, pos_a=pos_a, pos_b=pos_b, count=count)


def intersect_dot(a_keys, a_vals, b_keys, b_vals) -> jax.Array:
    """Sparse-sparse dot product: sum of products over the key intersection.

    This is the innermost SpMSpM primitive (Fig. 5 of the paper): the SUs
    intersect the two index streams and the FPU multiply-accumulates only on
    matches.
    """
    res = intersect(a_keys, b_keys)
    cap_a = a_keys.shape[0]
    valid = jnp.arange(cap_a) < res.count
    av = jnp.where(valid, a_vals[jnp.minimum(res.pos_a, cap_a - 1)], 0)
    bv = jnp.where(valid, b_vals[jnp.minimum(res.pos_b, b_keys.shape[0] - 1)], 0)
    return jnp.sum(av * bv)


def union_add(a_keys, a_vals, b_keys, b_vals) -> UnionResult:
    """Sorted-stream union with add-combine (SU merge mode).

    Used for sparse accumulation (SpMSpM row merging) and for sparse gradient
    all-reduce in ``repro.grad_comp``: combining two workers' top-k gradient
    streams is exactly this op.
    """
    keys = jnp.concatenate([a_keys, b_keys]).astype(jnp.int32)
    vals = jnp.concatenate([a_vals, b_vals])
    order = jnp.argsort(keys)
    keys, vals = keys[order], vals[order]
    n = keys.shape[0]
    is_new = jnp.concatenate([jnp.array([True]), keys[1:] != keys[:-1]])
    is_new = is_new & (keys != INVALID_KEY)
    slot = jnp.cumsum(is_new) - 1                      # output slot per element
    slot = jnp.where(keys == INVALID_KEY, n - 1, slot)  # dump padding at the end
    count = is_new.sum().astype(jnp.int32)
    out_vals = jnp.zeros(n, vals.dtype).at[slot].add(
        jnp.where(keys == INVALID_KEY, 0, vals))
    out_keys = jnp.full(n, INVALID_KEY, jnp.int32).at[slot].set(
        jnp.where(keys == INVALID_KEY, INVALID_KEY, keys))
    # Ensure padding slots (>= count) read INVALID even if slot n-1 was touched.
    idx = jnp.arange(n)
    out_keys = jnp.where(idx < count, out_keys, INVALID_KEY)
    out_vals = jnp.where(idx < count, out_vals, 0)
    return UnionResult(keys=out_keys, values=out_vals, count=count)


def topk_sparsify(x: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Flatten ``x`` and keep the k largest-magnitude entries as a sorted
    (keys, values) stream -- the producer side of sparse gradient exchange."""
    flat = x.reshape(-1)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    idx = jnp.sort(idx).astype(jnp.int32)
    return idx, flat[idx]


def stream_densify(keys: jax.Array, values: jax.Array, count: jax.Array,
                   size: int) -> jax.Array:
    """Scatter a (keys, values, count) stream back to a dense vector."""
    valid = jnp.arange(keys.shape[0]) < count
    safe = jnp.where(valid, keys, 0).astype(jnp.int32)
    return jnp.zeros(size, values.dtype).at[safe].add(jnp.where(valid, values, 0))
