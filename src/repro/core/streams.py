"""Affine stream descriptors -- the software model of Occamy's SU streams.

An Occamy SU is programmed with up to four (bound, stride) pairs and a base
pointer; thereafter reads/writes of a register deliver the stream at FPU rate.
Here a :class:`StreamSpec` captures the same iteration space and compiles to
either (a) a pure-JAX gather (reference semantics, any backend) or (b) a Pallas
``BlockSpec`` + ``index_map`` pair, where the Pallas grid pipeline plays the
role of the SU+DMA double-buffering.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """<=4-D affine stream: ``addr(i0..ik) = base + sum_d i_d * stride_d``.

    ``bounds``/``strides`` are in *elements* of the flattened operand, highest
    dimension first, mirroring the SU register programming model.
    """

    base: int
    bounds: Tuple[int, ...]
    strides: Tuple[int, ...]

    def __post_init__(self):
        assert 1 <= len(self.bounds) <= 4, "Occamy SUs support <=4-D streams"
        assert len(self.bounds) == len(self.strides)

    @property
    def length(self) -> int:
        return int(np.prod(self.bounds))

    def offsets(self) -> np.ndarray:
        """Materialized address stream (host-side; for tests/oracles)."""
        grids = np.meshgrid(*[np.arange(b) for b in self.bounds], indexing="ij")
        off = np.full(grids[0].shape, self.base, np.int64)
        for g, s in zip(grids, self.strides):
            off = off + g * s
        return off.reshape(-1)

    def read(self, flat: jax.Array) -> jax.Array:
        """Reference affine-stream read (pure JAX gather)."""
        return jnp.take(flat.reshape(-1), jnp.asarray(self.offsets()), axis=0)

    @staticmethod
    def for_tensor(shape: Sequence[int], order: Sequence[int] | None = None) -> "StreamSpec":
        """Stream that walks ``shape`` in ``order`` (default: row-major)."""
        shape = tuple(shape)
        row_major_strides = []
        acc = 1
        for s in reversed(shape):
            row_major_strides.append(acc)
            acc *= s
        row_major_strides = list(reversed(row_major_strides))
        order = tuple(order) if order is not None else tuple(range(len(shape)))
        return StreamSpec(
            base=0,
            bounds=tuple(shape[d] for d in order),
            strides=tuple(row_major_strides[d] for d in order),
        )


@dataclasses.dataclass(frozen=True)
class IndirectStream:
    """Indexed stream: ``addr(i) = base + idx[i] * stride`` (SU indirection).

    ``idx`` may be int8/16/32 in hardware; here always int32 after widening.
    """

    indices: jax.Array  # (n,) int32
    stride: int = 1
    base: int = 0

    def read(self, flat: jax.Array) -> jax.Array:
        addr = self.base + self.indices.astype(jnp.int32) * self.stride
        return jnp.take(flat.reshape(-1), addr, axis=0)

    def write(self, flat: jax.Array, values: jax.Array, accumulate: bool = True) -> jax.Array:
        addr = self.base + self.indices.astype(jnp.int32) * self.stride
        flat = flat.reshape(-1)
        if accumulate:
            return flat.at[addr].add(values)
        return flat.at[addr].set(values)
