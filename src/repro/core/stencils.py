"""Stencil specifications and reference application.

The paper's headline stencil is j3d27pt (3-D 27-point Jacobi box, 83% FPU
util); we carry the full family it benchmarks in Fig. 6a. A stencil is a set
of (offset, coefficient) taps; applying it at every interior point is a
gather-FMA chain that Occamy's SUs stream. The reference here uses shifted
slices (pure JAX); ``repro.kernels.stencil`` is the Pallas streaming version.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class StencilSpec:
    name: str
    ndim: int
    offsets: Tuple[Tuple[int, ...], ...]  # taps, each of length ndim
    coeffs: Tuple[float, ...]

    @property
    def points(self) -> int:
        return len(self.offsets)

    @property
    def radius(self) -> int:
        return max(max(abs(o) for o in off) for off in self.offsets)

    def flops_per_point(self) -> int:
        # one multiply + one add per tap (FMA counts as 2 flops)
        return 2 * self.points


def _star(ndim: int, radius: int = 1) -> Tuple[Tuple[int, ...], ...]:
    offs = [tuple([0] * ndim)]
    for d in range(ndim):
        for r in range(1, radius + 1):
            for s in (-r, r):
                o = [0] * ndim
                o[d] = s
                offs.append(tuple(o))
    return tuple(offs)


def _box(ndim: int, radius: int = 1) -> Tuple[Tuple[int, ...], ...]:
    return tuple(itertools.product(range(-radius, radius + 1), repeat=ndim))


def _mk(name, ndim, offsets):
    rng = np.random.default_rng(len(name) * 7 + ndim)  # fixed, reproducible taps
    coeffs = tuple((rng.random(len(offsets)) * 0.2 + 0.01).tolist())
    return StencilSpec(name=name, ndim=ndim, offsets=offsets, coeffs=coeffs)


STENCILS: Dict[str, StencilSpec] = {
    "j2d5pt": _mk("j2d5pt", 2, _star(2, 1)),
    "j2d9pt": _mk("j2d9pt", 2, _box(2, 1)),
    "j2d9pt-gol": _mk("j2d9pt-gol", 2, _star(2, 2)),  # star radius-2 (9 taps)
    "j3d7pt": _mk("j3d7pt", 3, _star(3, 1)),
    "j3d27pt": _mk("j3d27pt", 3, _box(3, 1)),
}


def apply_reference(spec: StencilSpec, grid: jax.Array) -> jax.Array:
    """Shifted-slice reference: output is the valid interior.

    ``grid`` includes the halo; output shape = grid.shape - 2*radius per dim.
    """
    r = spec.radius
    out_shape = tuple(s - 2 * r for s in grid.shape)
    acc = jnp.zeros(out_shape, jnp.promote_types(grid.dtype, jnp.float32))
    for off, c in zip(spec.offsets, spec.coeffs):
        start = tuple(r + o for o in off)
        sl = tuple(slice(s, s + n) for s, n in zip(start, out_shape))
        acc = acc + c * grid[sl].astype(acc.dtype)
    return acc.astype(grid.dtype)


def apply_gather_baseline(spec: StencilSpec, grid: jax.Array) -> jax.Array:
    """The *no-SU* baseline: explicit index computation + per-tap gather.

    Mirrors the paper's assembly-optimized scalar RISC-V baseline, where every
    tap costs address arithmetic + a load; used for Fig. 6a's +/- SU contrast.
    """
    r = spec.radius
    out_shape = tuple(s - 2 * r for s in grid.shape)
    flat = grid.reshape(-1)
    strides = np.cumprod((1,) + grid.shape[:0:-1])[::-1]  # row-major strides
    mesh = jnp.meshgrid(*[jnp.arange(r, r + n) for n in out_shape], indexing="ij")
    base = sum(m * int(s) for m, s in zip(mesh, strides))
    acc = jnp.zeros(out_shape, jnp.promote_types(grid.dtype, jnp.float32))
    for off, c in zip(spec.offsets, spec.coeffs):
        delta = int(sum(o * int(s) for o, s in zip(off, strides)))
        acc = acc + c * jnp.take(flat, (base + delta).reshape(-1)).reshape(out_shape)
    return acc.astype(grid.dtype)
