"""Multi-precision policy: the TPU translation of Occamy's FP64..FP8 ladder.

Occamy's SIMD FPUs run FP64/32/16/8 with *widening* sum-dot-product (FP8/16
inputs accumulating into wider formats). TPU v5e natively runs bf16 x bf16 ->
f32 and fp8 x fp8 -> f32 on the MXU -- the same widening-accumulate idea. FP64
has no TPU datapath (recorded in DESIGN.md S7); f32 is the "wide" anchor.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

LADDER: Dict[str, jnp.dtype] = {
    "f32": jnp.float32,          # stands in for the paper's FP64 anchor
    "bf16": jnp.bfloat16,        # FP16-class
    "fp8_e4m3": jnp.float8_e4m3fn,   # FP8 (4,3) == paper's FP8alt layout
    "fp8_e5m2": jnp.float8_e5m2,     # FP8 (5,2) == paper's FP8
}

# Peak per-chip throughput multipliers vs f32 on the v5e MXU ladder.
PEAK_MULTIPLIER = {"f32": 1.0, "bf16": 2.0, "fp8_e4m3": 4.0, "fp8_e5m2": 4.0}


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """param/compute/accum dtype triple with widening accumulation."""

    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.bfloat16
    accum_dtype: jnp.dtype = jnp.float32

    def cast_in(self, *xs):
        out = tuple(x.astype(self.compute_dtype) for x in xs)
        return out if len(out) > 1 else out[0]

    def dot(self, a: jax.Array, b: jax.Array, **kw) -> jax.Array:
        """Widening dot: inputs in compute dtype, accumulate in accum dtype."""
        a, b = self.cast_in(a, b)
        return jnp.matmul(a, b, preferred_element_type=self.accum_dtype, **kw)

    def einsum(self, expr: str, *xs) -> jax.Array:
        xs = tuple(x.astype(self.compute_dtype) for x in xs)
        return jnp.einsum(expr, *xs, preferred_element_type=self.accum_dtype)


def policy(name: str = "bf16") -> PrecisionPolicy:
    """Named policies for the ladder; ``name`` is the compute dtype."""
    cd = LADDER[name]
    return PrecisionPolicy(param_dtype=jnp.float32, compute_dtype=cd,
                           accum_dtype=jnp.float32)


def widening_sum_dot(a: jax.Array, b: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    """ExSdotp analogue [Bertaccini, ARITH'22]: fp8/bf16 pairs -> wide sum.

    On TPU this lowers to the MXU's native mixed-precision matmul; here it is
    the documented primitive the precision benchmarks exercise.
    """
    return jnp.sum(a.astype(out_dtype) * b.astype(out_dtype), axis=-1)


# ---------------------------------------------------------------------------
# BlockQuant: per-block-scaled narrow storage (the 8-bit end of the ladder).
#
# Occamy streams FP8/FP16 operands through *wide* accumulators (ExSdotp);
# the repro's translation is symmetric per-block quantization: narrow values
# (fp8 e4m3 / e5m2 / int8) plus one f32 scale per block, dequantized with a
# single multiply right before the f32-resident accumulator.  The dequant
# contract is ``values.astype(f32) * scale`` -- *exactly* that expression, in
# that order -- so a kernel that applies the scale in VMEM is bit-identical
# to dequantizing on host and running the f32 kernel.
# ---------------------------------------------------------------------------

QUANT_DTYPES: Dict[str, jnp.dtype] = {
    "fp8_e4m3": jnp.float8_e4m3fn,
    "fp8_e5m2": jnp.float8_e5m2,
    "int8": jnp.int8,
}

# Largest representable magnitude per narrow format (symmetric: int8 uses
# +/-127 so the scale grid has no asymmetric -128 corner).
QUANT_MAX: Dict[str, float] = {
    "fp8_e4m3": 448.0,
    "fp8_e5m2": 57344.0,
    "int8": 127.0,
}

# f32 mantissa bits dropped when truncating to each narrow float: the dither
# width of the stochastic-rounding bit trick.
_SR_DROP_BITS = {"fp8_e4m3": 23 - 3, "fp8_e5m2": 23 - 2}


def quant_name(dtype) -> str | None:
    """Reverse lookup: narrow storage dtype -> ladder name (None if wide)."""
    d = jnp.dtype(dtype)
    for name, q in QUANT_DTYPES.items():
        if jnp.dtype(q) == d:
            return name
    return None


def is_narrow(dtype) -> bool:
    """True for 1-byte block-value dtypes (fp8 variants / int8)."""
    return quant_name(dtype) is not None


def _resolve_quant(dtype) -> Tuple[str, jnp.dtype, float]:
    if isinstance(dtype, str):
        name = dtype
        if name not in QUANT_DTYPES:
            raise ValueError(f"unknown quant dtype {name!r}; "
                             f"choose from {sorted(QUANT_DTYPES)}")
        return name, QUANT_DTYPES[name], QUANT_MAX[name]
    name = quant_name(dtype)
    if name is None:
        raise ValueError(f"{jnp.dtype(dtype)} is not a narrow quant dtype; "
                         f"choose from {sorted(QUANT_DTYPES)}")
    return name, QUANT_DTYPES[name], QUANT_MAX[name]


def _sr_key(seed: int, salt: int) -> jax.Array:
    """Deterministic key derivation: an explicit integer seed folded with a
    per-call-site salt.  No global or threaded key state -- the same
    ``seed`` yields bit-identical rounding across calls and under jit."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), salt)


def stochastic_round(x: jax.Array, dtype, *, seed: int = 0,
                     salt: int = 0) -> jax.Array:
    """Stochastically round ``x`` (f32) to a narrow dtype, deterministically.

    Float targets use the mantissa-dither trick: add uniform random bits
    below the target mantissa to the magnitude bit pattern, then truncate --
    each value rounds up with probability equal to its fractional distance.
    int8 targets add uniform [0, 1) and floor.  The key is derived from
    ``(seed, salt)`` only, so identical inputs + seed give identical bits on
    every call, eager or jitted.
    """
    name, qdtype, qmax = _resolve_quant(dtype)
    x = jnp.clip(x.astype(jnp.float32), -qmax, qmax)
    key = _sr_key(seed, salt)
    if name == "int8":
        u = jax.random.uniform(key, x.shape, jnp.float32)
        return jnp.clip(jnp.floor(x + u), -127, 127).astype(jnp.int8)
    drop = _SR_DROP_BITS[name]
    sign = jnp.signbit(x)
    bits = jnp.abs(x).view(jnp.uint32)
    dither = jax.random.bits(key, x.shape, jnp.uint32) % jnp.uint32(1 << drop)
    bits = bits + dither
    bits = bits & jnp.uint32(~((1 << drop) - 1) & 0xFFFFFFFF)
    mag = bits.view(jnp.float32)
    y = jnp.where(sign, -mag, mag)
    # Truncated magnitudes are exactly representable (modulo the clip at
    # qmax, which the re-clip below restores), so astype cannot re-round.
    return jnp.clip(y, -qmax, qmax).astype(qdtype)


def _round_to(x: jax.Array, dtype, rounding: str, seed: int) -> jax.Array:
    """Round pre-scaled f32 values into the narrow grid."""
    name, qdtype, qmax = _resolve_quant(dtype)
    if rounding == "stochastic":
        return stochastic_round(x, name, seed=seed)
    if rounding != "nearest":
        raise ValueError(f"rounding must be 'nearest' or 'stochastic', "
                         f"got {rounding!r}")
    x = jnp.clip(x.astype(jnp.float32), -qmax, qmax)
    if name == "int8":
        return jnp.clip(jnp.round(x), -127, 127).astype(jnp.int8)
    return x.astype(qdtype)  # native round-to-nearest-even


def _amax_scale(x: jax.Array, axes, qmax: float) -> jax.Array:
    amax = jnp.max(jnp.abs(x), axis=axes)
    return jnp.where(amax > 0, amax / qmax, 1.0).astype(jnp.float32)


_SAT_MAX = 3.0e38  # < f32max with headroom: qmax * (SAT_MAX / qmax) stays
                   # finite after the scale's round-to-nearest, so a
                   # saturated stream dequantizes to finite values


def _guard_nonfinite(x: jax.Array, who: str, saturate: bool) -> jax.Array:
    """Non-finite input otherwise corrupts the quantized stream *silently*:
    an Inf amax yields an Inf scale (dequant NaN), a NaN amax fails the
    ``amax > 0`` gate and quantizes the row against scale 1.0 (values
    zeroed / NaN-cast).  ``saturate=True`` deterministically clamps
    (NaN -> 0, +/-Inf -> +/-3e38) in-graph; by default, concrete inputs
    raise ``FloatingPointError`` instead.  Traced inputs cannot be
    value-checked, so under jit the check is a no-op unless saturating --
    runtime poison under jit is the serving health layer's job."""
    if saturate:
        return jnp.where(jnp.isnan(x), jnp.float32(0.0),
                         jnp.clip(x, -_SAT_MAX, _SAT_MAX))
    if not isinstance(x, jax.core.Tracer) and not bool(jnp.isfinite(x).all()):
        raise FloatingPointError(
            f"{who}: non-finite input would produce a non-finite amax scale "
            f"and poison the quantized stream; pass saturate=True to clamp "
            f"deterministically instead")
    return x


def quantize_blocks(blocks: jax.Array, dtype, *, rounding: str = "nearest",
                    seed: int = 0, saturate: bool = False
                    ) -> Tuple[jax.Array, jax.Array]:
    """Per-block symmetric quantization of a ``(..., nnzb, bm, bn)`` stream.

    One f32 scale per (bm, bn) block: ``scale = max|block| / qmax`` (1.0 for
    all-zero blocks so dequant is exact and divisions are safe).  Returns
    ``(values, scales)`` with ``values.shape == blocks.shape`` and
    ``scales.shape == blocks.shape[:-2]``.
    """
    x = _guard_nonfinite(blocks.astype(jnp.float32), "quantize_blocks",
                         saturate)
    _, _, qmax = _resolve_quant(dtype)
    scales = _amax_scale(x, (-2, -1), qmax)
    q = _round_to(x / scales[..., None, None], dtype, rounding, seed)
    return q, scales


def dequantize_blocks(values: jax.Array, scales: jax.Array) -> jax.Array:
    """Inverse of :func:`quantize_blocks`: ``values.astype(f32) * scale``.

    This expression *is* the bit-identity contract -- the quantized kernels
    compute it verbatim per stream block before the f32 accumulator.
    """
    return values.astype(jnp.float32) * scales[..., None, None].astype(jnp.float32)


def quantize_rows(vals: jax.Array, dtype, *, rounding: str = "nearest",
                  seed: int = 0, saturate: bool = False
                  ) -> Tuple[jax.Array, jax.Array]:
    """Per-row quantization over the *last* axis: ELL row streams
    ``(R, la)`` and KV time-slices ``(..., t, head_dim)`` both scale over
    their trailing axis.  Returns ``(values, scales)`` with
    ``scales.shape == vals.shape[:-1]``."""
    x = _guard_nonfinite(vals.astype(jnp.float32), "quantize_rows", saturate)
    _, _, qmax = _resolve_quant(dtype)
    scales = _amax_scale(x, -1, qmax)
    q = _round_to(x / scales[..., None], dtype, rounding, seed)
    return q, scales


def dequantize_rows(values: jax.Array, scales: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`quantize_rows` (same op-order contract)."""
    return (values.astype(jnp.float32)
            * scales[..., None].astype(jnp.float32)).astype(dtype)


@dataclasses.dataclass(frozen=True)
class QuantTensor:
    """A dense tensor stored as narrow values + f32 scales over ``axis``.

    Registered as a pytree (``axis`` static) so it passes through jit /
    device_put / checkpoint flattening as two leaves.  ``shape``/``dtype``
    mirror the values array so shape-probing callers need no special case.
    """

    values: jax.Array   # narrow storage (fp8 / int8)
    scales: jax.Array   # f32, values.shape with ``axis`` removed
    axis: int           # reduction axis the scales were computed over

    @property
    def shape(self):
        return self.values.shape

    @property
    def ndim(self):
        return self.values.ndim

    @property
    def dtype(self):
        return self.values.dtype

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        s = jnp.expand_dims(self.scales, self.axis)
        return (self.values.astype(jnp.float32)
                * s.astype(jnp.float32)).astype(dtype)


jax.tree_util.register_pytree_node(
    QuantTensor,
    lambda t: ((t.values, t.scales), t.axis),
    lambda axis, kids: QuantTensor(values=kids[0], scales=kids[1], axis=axis),
)


def quantize_tensor(x: jax.Array, dtype, *, axis: int = -1,
                    rounding: str = "nearest", seed: int = 0,
                    saturate: bool = False) -> QuantTensor:
    """Quantize a dense tensor with one scale per slice along ``axis``
    (the reduction axis of the consuming contraction, so scale error stays
    per-output-channel).  Returns a :class:`QuantTensor` pytree.

    A *negative* ``axis`` is stored as-is, which makes the QuantTensor
    slice-stable: stripping leading (stacking/batch) dims via ``lax.scan``
    or per-leaf indexing keeps the stored axis pointing at the same
    trailing dimension."""
    if not -x.ndim <= axis < x.ndim:
        raise ValueError(f"quantize_tensor: axis {axis} out of range for "
                         f"ndim {x.ndim}")
    xf = _guard_nonfinite(x.astype(jnp.float32), "quantize_tensor", saturate)
    _, _, qmax = _resolve_quant(dtype)
    scales = _amax_scale(xf, axis, qmax)
    q = _round_to(xf / jnp.expand_dims(scales, axis), dtype, rounding, seed)
    return QuantTensor(values=q, scales=scales, axis=axis)
