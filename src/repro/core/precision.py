"""Multi-precision policy: the TPU translation of Occamy's FP64..FP8 ladder.

Occamy's SIMD FPUs run FP64/32/16/8 with *widening* sum-dot-product (FP8/16
inputs accumulating into wider formats). TPU v5e natively runs bf16 x bf16 ->
f32 and fp8 x fp8 -> f32 on the MXU -- the same widening-accumulate idea. FP64
has no TPU datapath (recorded in DESIGN.md S7); f32 is the "wide" anchor.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

LADDER: Dict[str, jnp.dtype] = {
    "f32": jnp.float32,          # stands in for the paper's FP64 anchor
    "bf16": jnp.bfloat16,        # FP16-class
    "fp8_e4m3": jnp.float8_e4m3fn,   # FP8 (4,3) == paper's FP8alt layout
    "fp8_e5m2": jnp.float8_e5m2,     # FP8 (5,2) == paper's FP8
}

# Peak per-chip throughput multipliers vs f32 on the v5e MXU ladder.
PEAK_MULTIPLIER = {"f32": 1.0, "bf16": 2.0, "fp8_e4m3": 4.0, "fp8_e5m2": 4.0}


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """param/compute/accum dtype triple with widening accumulation."""

    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.bfloat16
    accum_dtype: jnp.dtype = jnp.float32

    def cast_in(self, *xs):
        out = tuple(x.astype(self.compute_dtype) for x in xs)
        return out if len(out) > 1 else out[0]

    def dot(self, a: jax.Array, b: jax.Array, **kw) -> jax.Array:
        """Widening dot: inputs in compute dtype, accumulate in accum dtype."""
        a, b = self.cast_in(a, b)
        return jnp.matmul(a, b, preferred_element_type=self.accum_dtype, **kw)

    def einsum(self, expr: str, *xs) -> jax.Array:
        xs = tuple(x.astype(self.compute_dtype) for x in xs)
        return jnp.einsum(expr, *xs, preferred_element_type=self.accum_dtype)


def policy(name: str = "bf16") -> PrecisionPolicy:
    """Named policies for the ladder; ``name`` is the compute dtype."""
    cd = LADDER[name]
    return PrecisionPolicy(param_dtype=jnp.float32, compute_dtype=cd,
                           accum_dtype=jnp.float32)


def widening_sum_dot(a: jax.Array, b: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    """ExSdotp analogue [Bertaccini, ARITH'22]: fp8/bf16 pairs -> wide sum.

    On TPU this lowers to the MXU's native mixed-precision matmul; here it is
    the documented primitive the precision benchmarks exercise.
    """
    return jnp.sum(a.astype(out_dtype) * b.astype(out_dtype), axis=-1)
