"""Core: the paper's contribution (streams, SU ops, sparse formats, stencils,
multi-precision) as a composable JAX library."""
from repro.core.formats import (BCSR, CSR, INVALID_KEY, BatchedBCSR, SortedCOO,
                                banded_sparse, batched_bcsr_from_dense,
                                bcsr_from_dense, coo_from_dense,
                                csr_from_dense, powerlaw_sparse,
                                random_dense_sparse)
from repro.core.masks import (NEG_INF, AttnMaskSpec, BlockMask, MaskStream,
                              next_pow2)
from repro.core.precision import LADDER, PrecisionPolicy, policy
from repro.core.stencils import STENCILS, StencilSpec, apply_reference
from repro.core.streams import IndirectStream, StreamSpec
from repro.core.su import (indirect_gather, indirect_scatter_add, intersect,
                           intersect_dot, topk_sparsify, union_add)

__all__ = [
    "BCSR", "BatchedBCSR", "CSR", "SortedCOO", "INVALID_KEY",
    "banded_sparse", "batched_bcsr_from_dense", "bcsr_from_dense",
    "coo_from_dense", "csr_from_dense",
    "powerlaw_sparse", "random_dense_sparse",
    "NEG_INF", "AttnMaskSpec", "BlockMask", "MaskStream", "next_pow2",
    "LADDER", "PrecisionPolicy", "policy",
    "STENCILS", "StencilSpec", "apply_reference",
    "IndirectStream", "StreamSpec",
    "indirect_gather", "indirect_scatter_add", "intersect", "intersect_dot",
    "topk_sparsify", "union_add",
]
