"""Sparse tensor containers and generators.

Occamy's SUs consume *sorted index streams* over scratchpad-resident data.
On TPU the efficient quantum of data movement is a (>=8, >=128) tile, so the
central format here is **BCSR** (block compressed sparse row): the block-column
index stream drives which dense tile the DMA engine (the Pallas pipeline)
fetches next -- the faithful TPU re-granularization of SU indirection.

All containers are registered pytrees with static shape metadata, so they pass
through ``jax.jit`` unscathed (nnz is fixed at construction time, as required
for XLA's static shapes).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .precision import dequantize_blocks, is_narrow, quantize_blocks


def _pytree_dataclass(cls=None, *, static: Tuple[str, ...] = ()):
    """Register a dataclass as a pytree with ``static`` fields as aux data."""

    def wrap(c):
        c = dataclasses.dataclass(frozen=True)(c)
        data_fields = tuple(f.name for f in dataclasses.fields(c) if f.name not in static)

        def flatten(obj):
            return (
                tuple(getattr(obj, f) for f in data_fields),
                tuple(getattr(obj, f) for f in static),
            )

        def unflatten(aux, children):
            kwargs = dict(zip(data_fields, children))
            kwargs.update(dict(zip(static, aux)))
            return c(**kwargs)

        jax.tree_util.register_pytree_node(c, flatten, unflatten)
        return c

    return wrap if cls is None else wrap(cls)


@_pytree_dataclass(static=("shape",))
class CSR:
    """Element-granular CSR; the *reference* format (Occamy's native view)."""

    indptr: jax.Array   # (n_rows + 1,) int32
    indices: jax.Array  # (nnz,) int32, column ids, sorted within each row
    values: jax.Array   # (nnz,) float
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return self.values.shape[0]

    def todense(self) -> jax.Array:
        n_rows, n_cols = self.shape
        row_ids = jnp.repeat(
            jnp.arange(n_rows, dtype=jnp.int32),
            jnp.diff(self.indptr),
            total_repeat_length=self.nnz,
        )
        out = jnp.zeros(self.shape, self.values.dtype)
        return out.at[row_ids, self.indices].add(self.values)


@_pytree_dataclass(static=("shape", "block"))
class BCSR:
    """Block-CSR with a *flattened block stream* (megablox-style).

    ``blocks[i]`` is the i-th nonzero (bm, bn) tile in block-row-major order;
    ``block_rows[i]`` / ``block_cols[i]`` are its block coordinates. This is
    the index stream handed to the SpMM kernel's scalar prefetch: exactly the
    SU "index stream drives data stream" contract.

    Narrow (fp8 / int8) block values carry per-block f32 ``scales`` alongside
    the index stream (the BlockQuant scheme, ``core.precision``): block ``i``
    dequantizes as ``blocks[i].astype(f32) * scales[i]``.  Wide values leave
    ``scales`` as None -- that path is byte-identical to the pre-quant format.
    """

    indptr: jax.Array      # (n_brows + 1,) int32 -- offsets into the block stream
    block_rows: jax.Array  # (nnzb,) int32
    block_cols: jax.Array  # (nnzb,) int32
    blocks: jax.Array      # (nnzb, bm, bn) float
    shape: Tuple[int, int]
    block: Tuple[int, int]
    scales: Optional[jax.Array] = None  # (nnzb,) f32 per-block dequant scales

    def __post_init__(self):
        _check_quant_consistency("BCSR", self.blocks, self.scales, 1)

    @property
    def nnzb(self) -> int:
        return self.blocks.shape[0]

    @property
    def grid_shape(self) -> Tuple[int, int]:
        return (self.shape[0] // self.block[0], self.shape[1] // self.block[1])

    def quantize(self, dtype, *, rounding: str = "nearest",
                 seed: int = 0) -> "BCSR":
        """Per-block-scaled narrow copy (same index stream)."""
        q, s = quantize_blocks(self.blocks, dtype, rounding=rounding, seed=seed)
        return dataclasses.replace(self, blocks=q, scales=s)

    def dequantize(self) -> "BCSR":
        """f32 copy with scales folded back into the block values."""
        if self.scales is None:
            return self
        return dataclasses.replace(
            self, blocks=dequantize_blocks(self.blocks, self.scales),
            scales=None)

    def todense(self) -> jax.Array:
        if self.scales is not None:
            return self.dequantize().todense()
        bm, bn = self.block
        gm, gn = self.grid_shape
        dense = jnp.zeros((gm, gn, bm, bn), self.blocks.dtype)
        dense = dense.at[self.block_rows, self.block_cols].add(self.blocks)
        return dense.transpose(0, 2, 1, 3).reshape(self.shape)

    def density(self) -> float:
        gm, gn = self.grid_shape
        return self.nnzb / float(gm * gn)


def _check_quant_consistency(cls_name: str, blocks, scales, lead_ndim: int):
    """Construction-time value-dtype / scale-shape validation.

    Narrow (1-byte) block values without scales would silently upcast into
    garbage downstream (the kernels would treat raw quantized codes as
    magnitudes); mis-shaped scales would broadcast wrongly.  Both raise here,
    with shapes in the message.  hasattr-guarded so non-array placeholders
    flowing through pytree unflatten (tree_map outputs, ShapeDtypeStructs
    without dtype, etc.) pass through untouched.
    """
    if scales is not None and hasattr(blocks, "shape") and hasattr(scales, "shape"):
        want = tuple(blocks.shape[:lead_ndim])
        if tuple(scales.shape) != want:
            raise ValueError(
                f"{cls_name}: scales shape {tuple(scales.shape)} does not "
                f"match blocks {tuple(blocks.shape)} (expected per-block "
                f"scales of shape {want})")
        if hasattr(scales, "dtype") and scales.dtype != jnp.float32:
            raise ValueError(
                f"{cls_name}: scales must be float32, got {scales.dtype}")
    if scales is None and hasattr(blocks, "dtype") and is_narrow(blocks.dtype):
        raise ValueError(
            f"{cls_name}: narrow block values ({blocks.dtype}, shape "
            f"{tuple(getattr(blocks, 'shape', ()))}) require per-block "
            "scales; quantize via .quantize()/core.precision.quantize_blocks "
            "instead of casting raw values")


@_pytree_dataclass(static=("shape", "block"))
class BatchedBCSR:
    """A batch of BCSR matrices sharing ONE index stream.

    Occamy replicates the index stream across clusters while each cluster's
    SPM holds different data tiles; the batched container mirrors that:
    ``indptr``/``block_rows``/``block_cols`` describe the union sparsity
    pattern once, and ``blocks`` carries per-batch values ``(B, nnzb, bm,
    bn)``.  Matrices whose pattern is a subset of the union simply hold zero
    blocks at the extra positions -- same math, static shapes, and the whole
    container is ``vmap``-compatible over the leading blocks axis (the index
    arrays broadcast).  MoE-style workloads (one sparse dispatch per expert)
    batch through here.
    """

    indptr: jax.Array      # (n_brows + 1,) int32 -- shared across the batch
    block_rows: jax.Array  # (nnzb,) int32 -- shared
    block_cols: jax.Array  # (nnzb,) int32 -- shared
    blocks: jax.Array      # (B, nnzb, bm, bn) float
    shape: Tuple[int, int, int]   # (B, M, K)
    block: Tuple[int, int]
    scales: Optional[jax.Array] = None  # (B, nnzb) f32 per-block scales

    def __post_init__(self):
        _check_quant_consistency("BatchedBCSR", self.blocks, self.scales, 2)

    @property
    def batch(self) -> int:
        return self.blocks.shape[0]

    @property
    def nnzb(self) -> int:
        return self.blocks.shape[1]

    @property
    def grid_shape(self) -> Tuple[int, int]:
        return (self.shape[1] // self.block[0], self.shape[2] // self.block[1])

    def __getitem__(self, i: int) -> "BCSR":
        """Static (python-int) batch element as a plain BCSR view."""
        return BCSR(indptr=self.indptr, block_rows=self.block_rows,
                    block_cols=self.block_cols, blocks=self.blocks[i],
                    shape=self.shape[1:], block=self.block,
                    scales=None if self.scales is None else self.scales[i])

    def quantize(self, dtype, *, rounding: str = "nearest",
                 seed: int = 0) -> "BatchedBCSR":
        """Per-block-scaled narrow copy (same shared index stream)."""
        q, s = quantize_blocks(self.blocks, dtype, rounding=rounding, seed=seed)
        return dataclasses.replace(self, blocks=q, scales=s)

    def dequantize(self) -> "BatchedBCSR":
        """f32 copy with scales folded back into the block values."""
        if self.scales is None:
            return self
        return dataclasses.replace(
            self, blocks=dequantize_blocks(self.blocks, self.scales),
            scales=None)

    def with_capacity(self, nnzb_cap: int) -> "BatchedBCSR":
        """Pad the shared index stream to exactly ``nnzb_cap`` entries.

        Pad entries repeat the *last* stream entry's (row, col) coordinates
        with all-zero blocks, so the stream stays (row, col)-sorted, every
        block-row that appeared still appears, and the padded product is
        bit-identical (zero blocks accumulate zero).  This is how a
        data-dependent routed stream is snapped to a static *bucket* size:
        a jit-compiled consumer retraces per distinct capacity, never per
        raw nonzero count (see ``repro.kernels.engine.stream_bucket``).

        Host-side: the index stream must be concrete (it defines static
        geometry), so this cannot be called on traced containers.
        """
        nnzb = self.nnzb
        if nnzb_cap < nnzb:
            raise ValueError(
                f"with_capacity({nnzb_cap}): stream already holds {nnzb} "
                "blocks; capacity can only grow")
        if nnzb_cap == nnzb:
            return self
        if nnzb == 0:
            raise ValueError("with_capacity: cannot pad an empty stream "
                             "(no coordinates to repeat)")
        if isinstance(self.block_rows, jax.core.Tracer):
            raise TypeError(
                "with_capacity needs a concrete index stream (it fixes the "
                "static bucket geometry); build the plan eagerly, outside jit")
        pad = nnzb_cap - nnzb
        rows = np.asarray(self.block_rows)
        cols = np.asarray(self.block_cols)
        last_r = int(rows[-1])
        rows = np.concatenate([rows, np.full(pad, last_r, np.int32)])
        cols = np.concatenate([cols, np.full(pad, int(cols[-1]), np.int32)])
        indptr = np.asarray(self.indptr).copy()
        indptr[last_r + 1:] += pad
        blocks = jnp.concatenate(
            [self.blocks,
             jnp.zeros((self.batch, pad) + tuple(self.block),
                       self.blocks.dtype)], axis=1)
        scales = self.scales
        if scales is not None:
            # Zero pad blocks dequantize to zero under any scale; 1.0 keeps
            # the all-zero-block convention of quantize_blocks.
            scales = jnp.concatenate(
                [scales, jnp.ones((self.batch, pad), jnp.float32)], axis=1)
        return BatchedBCSR(indptr=jnp.asarray(indptr),
                           block_rows=jnp.asarray(rows),
                           block_cols=jnp.asarray(cols),
                           blocks=blocks, shape=self.shape, block=self.block,
                           scales=scales)

    def todense(self) -> jax.Array:
        if self.scales is not None:
            return self.dequantize().todense()
        bm, bn = self.block
        gm, gn = self.grid_shape
        dense = jnp.zeros((self.batch, gm, gn, bm, bn), self.blocks.dtype)
        dense = dense.at[:, self.block_rows, self.block_cols].add(self.blocks)
        return dense.transpose(0, 1, 3, 2, 4).reshape(self.shape)

    def density(self) -> float:
        gm, gn = self.grid_shape
        return self.nnzb / float(gm * gn)


@_pytree_dataclass(static=("shape",))
class SortedCOO:
    """Sorted coordinate stream: the SU *intersection/union* operand format.

    ``keys = row * n_cols + col`` sorted ascending; values aligned. A fixed
    capacity with an explicit ``count`` keeps shapes static under jit; slots
    past ``count`` hold the sentinel key ``INVALID`` (2**31 - 1).
    """

    keys: jax.Array    # (capacity,) int32, sorted; INVALID-padded
    values: jax.Array  # (capacity,) float
    count: jax.Array   # () int32
    shape: Tuple[int, int]

    @property
    def capacity(self) -> int:
        return self.keys.shape[0]

    def todense(self) -> jax.Array:
        n_rows, n_cols = self.shape
        valid = jnp.arange(self.capacity) < self.count
        rows = jnp.where(valid, self.keys // n_cols, 0)
        cols = jnp.where(valid, self.keys % n_cols, 0)
        vals = jnp.where(valid, self.values, 0)
        return jnp.zeros(self.shape, self.values.dtype).at[rows, cols].add(vals)


INVALID_KEY = np.int32(2**31 - 1)


# ---------------------------------------------------------------------------
# Converters (host-side, numpy): build static-shaped containers from dense.
# ---------------------------------------------------------------------------

def csr_from_dense(dense: np.ndarray) -> CSR:
    dense = np.asarray(dense)
    n_rows, _ = dense.shape
    mask = dense != 0
    indptr = np.zeros(n_rows + 1, np.int32)
    np.cumsum(mask.sum(axis=1), out=indptr[1:])
    rows, cols = np.nonzero(mask)
    return CSR(
        indptr=jnp.asarray(indptr),
        indices=jnp.asarray(cols.astype(np.int32)),
        values=jnp.asarray(dense[rows, cols]),
        shape=dense.shape,
    )


def bcsr_from_dense(dense: np.ndarray, block: Tuple[int, int]) -> BCSR:
    dense = np.asarray(dense)
    bm, bn = block
    m, n = dense.shape
    assert m % bm == 0 and n % bn == 0, f"shape {dense.shape} not divisible by block {block}"
    gm, gn = m // bm, n // bn
    tiles = dense.reshape(gm, bm, gn, bn).transpose(0, 2, 1, 3)  # (gm, gn, bm, bn)
    nz = np.abs(tiles).sum(axis=(2, 3)) != 0
    brows, bcols = np.nonzero(nz)
    indptr = np.zeros(gm + 1, np.int32)
    np.cumsum(nz.sum(axis=1), out=indptr[1:])
    return BCSR(
        indptr=jnp.asarray(indptr),
        block_rows=jnp.asarray(brows.astype(np.int32)),
        block_cols=jnp.asarray(bcols.astype(np.int32)),
        blocks=jnp.asarray(tiles[brows, bcols]),
        shape=(m, n),
        block=block,
    )


def batched_bcsr_from_dense(dense: np.ndarray, block: Tuple[int, int]
                            ) -> BatchedBCSR:
    """(B, M, K) dense stack -> BatchedBCSR over the *union* block pattern.

    The shared index stream is the union of the per-matrix nonzero-block
    masks, so one scalar-prefetch stream drives all batch elements (the
    replicated-index-stream contract).  Per-element blocks that are zero in
    a given matrix are stored as zero tiles.
    """
    dense = np.asarray(dense)
    assert dense.ndim == 3, dense.shape
    B, m, n = dense.shape
    bm, bn = block
    assert m % bm == 0 and n % bn == 0, f"shape {dense.shape} not divisible by block {block}"
    gm, gn = m // bm, n // bn
    tiles = dense.reshape(B, gm, bm, gn, bn).transpose(0, 1, 3, 2, 4)
    nz = (np.abs(tiles).sum(axis=(3, 4)) != 0).any(axis=0)   # (gm, gn) union
    brows, bcols = np.nonzero(nz)
    indptr = np.zeros(gm + 1, np.int32)
    np.cumsum(nz.sum(axis=1), out=indptr[1:])
    return BatchedBCSR(
        indptr=jnp.asarray(indptr),
        block_rows=jnp.asarray(brows.astype(np.int32)),
        block_cols=jnp.asarray(bcols.astype(np.int32)),
        blocks=jnp.asarray(tiles[:, brows, bcols]),
        shape=(B, m, n),
        block=block,
    )


def coo_from_dense(dense: np.ndarray, capacity: int | None = None) -> SortedCOO:
    dense = np.asarray(dense)
    n_rows, n_cols = dense.shape
    rows, cols = np.nonzero(dense)
    keys = (rows * n_cols + cols).astype(np.int32)
    order = np.argsort(keys, kind="stable")
    keys, vals = keys[order], dense[rows, cols][order]
    cap = capacity or len(keys)
    assert cap >= len(keys)
    pk = np.full(cap, INVALID_KEY, np.int32)
    pv = np.zeros(cap, dense.dtype)
    pk[: len(keys)] = keys
    pv[: len(keys)] = vals
    return SortedCOO(
        keys=jnp.asarray(pk), values=jnp.asarray(pv),
        count=jnp.asarray(len(keys), jnp.int32), shape=(n_rows, n_cols),
    )


# ---------------------------------------------------------------------------
# Generators: synthetic stand-ins for the paper's real-world SuiteSparse set.
# ---------------------------------------------------------------------------

def random_dense_sparse(rng: np.random.Generator, shape, density: float,
                        dtype=np.float32) -> np.ndarray:
    """Uniform-random sparsity (paper Fig. 6c right matrices: 1% random)."""
    mask = rng.random(shape) < density
    vals = rng.standard_normal(shape).astype(dtype)
    return np.where(mask, vals, 0).astype(dtype)


def banded_sparse(rng: np.random.Generator, shape, bandwidth: int,
                  dtype=np.float32) -> np.ndarray:
    """Banded matrix (stencil-like structure; e.g. FEM/FD matrices)."""
    m, n = shape
    i = np.arange(m)[:, None]
    j = np.arange(n)[None, :]
    mask = np.abs(i - j) <= bandwidth
    vals = rng.standard_normal(shape).astype(dtype)
    return np.where(mask, vals, 0).astype(dtype)


def powerlaw_sparse(rng: np.random.Generator, shape, density: float,
                    alpha: float = 1.5, dtype=np.float32) -> np.ndarray:
    """Power-law row degrees (graph adjacency-like; heavy row imbalance)."""
    m, n = shape
    target = int(density * m * n)
    weights = (np.arange(1, m + 1, dtype=np.float64)) ** (-alpha)
    weights /= weights.sum()
    row_nnz = np.minimum(rng.multinomial(target, weights), n)
    out = np.zeros(shape, dtype)
    for r in range(m):
        k = int(row_nnz[r])
        if k:
            cols = rng.choice(n, size=k, replace=False)
            out[r, cols] = rng.standard_normal(k).astype(dtype)
    return out


def block_sparse_mask(rng: np.random.Generator, grid_shape, density: float) -> np.ndarray:
    """Random block-level mask (for directly generating BCSR streams)."""
    return rng.random(grid_shape) < density
