"""Block-granular attention masks: the pattern type behind sparse flash.

A ``BlockMask`` records which (bq x bk) score tiles of an attention matrix
are visible, plus the *intra-tile* refinement each visible tile still needs
(causal edge, sliding-window edge).  It lowers to the same sorted
per-row (block_row, block_col) index-stream representation the BCSR
machinery uses (``core.formats`` / ``kernels.spmm``), so the flash kernel
can walk visible tiles only -- the Occamy stream-walk + resident-accumulator
discipline applied to attention instead of paying the full dense KV grid.

Representation: ``tile_kinds`` is an (n_q_tiles, n_kv_tiles) int8 map:

  * ``KIND_DEAD`` (-1): tile invisible -- never walked.
  * ``0``: fully visible, no intra-tile mask needed.
  * bit ``KIND_CAUSAL`` (1): apply ``q_pos >= k_pos`` inside the tile.
  * bit ``KIND_WINDOW`` (2): apply ``q_pos - k_pos < window`` inside the tile.

Bits compose, and composition of masks (``a & b`` / ``a | b``) composes the
bits per tile, which is what makes unions like ``local | global`` exactly
representable (the global-column tiles keep causal-only refinement while the
local band keeps the window edge).  Everything here is host-side numpy on
static shapes, so lowering runs at trace time and the streams reach the
kernel as compile-time-shaped operands -- recompiles are keyed on the
*bucketed stream length*, not on the pattern contents.
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np

# The one masking constant (satellite: grep-clean dedup of the -1e30 literal).
NEG_INF = -1e30

KIND_DEAD = -1
KIND_CAUSAL = 1
KIND_WINDOW = 2


def next_pow2(n: int, minimum: int = 8) -> int:
    """Smallest power of two >= max(n, minimum) (the PR-3 bucket law)."""
    n = max(int(n), minimum, 1)
    return 1 << (n - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class MaskStream:
    """Lowered block-index stream: the attention analogue of BCSR indices.

    ``rows``/``cols``/``kinds`` are (capacity,) int32, sorted by (row, col);
    every block-row appears at least once (empty rows carry one KIND_DEAD
    entry, like ``spmm.ops.pad_empty_rows``), and bucket padding repeats the
    last (row, col) with KIND_DEAD so pad steps are exact no-ops.
    """
    rows: np.ndarray
    cols: np.ndarray
    kinds: np.ndarray
    n_q_tiles: int
    nnzb: int            # live entries before bucket padding

    @property
    def capacity(self) -> int:
        return int(self.rows.shape[0])


class BlockMask:
    """Block-sparse attention visibility pattern over a (sq, skv) score grid.

    ``q_offset`` is the absolute position of local q row 0 (nonzero for
    sequence-sharded sub-masks); causal/window refinements always compare
    *absolute* positions, so a shard's sub-mask stays exact.
    """

    def __init__(self, sq: int, skv: int, bq: int, bk: int,
                 tile_kinds: np.ndarray, *, window: int | None = None,
                 q_offset: int = 0):
        assert sq >= 1 and skv >= 1 and bq >= 1 and bk >= 1
        n_q = -(-sq // bq)
        n_kv = -(-skv // bk)
        tile_kinds = np.asarray(tile_kinds, np.int8)
        assert tile_kinds.shape == (n_q, n_kv), (tile_kinds.shape, n_q, n_kv)
        if window is None:
            assert not ((tile_kinds >= 0)
                        & ((tile_kinds & KIND_WINDOW) > 0)).any(), \
                "window-refined tiles need an explicit window length"
        self.sq, self.skv, self.bq, self.bk = sq, skv, bq, bk
        self.window = window
        self.q_offset = q_offset
        self.tile_kinds = tile_kinds

    # ------------------------------------------------------------ geometry
    @property
    def n_q_tiles(self) -> int:
        return self.tile_kinds.shape[0]

    @property
    def n_kv_tiles(self) -> int:
        return self.tile_kinds.shape[1]

    @property
    def nnzb(self) -> int:
        return int((self.tile_kinds >= 0).sum())

    # -------------------------------------------------------- constructors
    @classmethod
    def full(cls, sq: int, skv: int, *, bq: int = 128, bk: int = 128,
             causal: bool = False, window: int | None = None,
             q_offset: int = 0) -> "BlockMask":
        """All in-range tiles, refined by the analytic causal/window edges;
        tiles with no visible (q, k) pair are pruned from the walk."""
        kind = 0
        if causal:
            kind |= KIND_CAUSAL
        if window is not None:
            kind |= KIND_WINDOW
        n_q, n_kv = -(-sq // bq), -(-skv // bk)
        kinds = np.full((n_q, n_kv), kind, np.int8)
        m = cls(sq, skv, bq, bk, kinds, window=window, q_offset=q_offset)
        return m._pruned()

    @classmethod
    def causal(cls, sq: int, skv: int, *, bq: int = 128, bk: int = 128,
               q_offset: int = 0) -> "BlockMask":
        return cls.full(sq, skv, bq=bq, bk=bk, causal=True, q_offset=q_offset)

    @classmethod
    def sliding_window(cls, sq: int, skv: int, window: int, *, bq: int = 128,
                       bk: int = 128, causal: bool = True,
                       q_offset: int = 0) -> "BlockMask":
        return cls.full(sq, skv, bq=bq, bk=bk, causal=causal, window=window,
                        q_offset=q_offset)

    @classmethod
    def strided(cls, sq: int, skv: int, stride: int, *, bq: int = 128,
                bk: int = 128, causal: bool = True,
                q_offset: int = 0) -> "BlockMask":
        """Every ``stride``-th KV block tile (the last of each group) is
        visible to all rows -- the Sparse-Transformer column pattern; compose
        with ``sliding_window`` for the usual local+strided mask."""
        m = cls.full(sq, skv, bq=bq, bk=bk, causal=causal, q_offset=q_offset)
        kinds = m.tile_kinds.copy()
        keep = (np.arange(m.n_kv_tiles) % stride) == (stride - 1)
        kinds[:, ~keep] = KIND_DEAD
        return cls(sq, skv, bq, bk, kinds, q_offset=q_offset)

    @classmethod
    def global_cols(cls, sq: int, skv: int, n_global: int, *, bq: int = 128,
                    bk: int = 128, causal: bool = True,
                    q_offset: int = 0) -> "BlockMask":
        """The first ``n_global`` KV block tiles visible to every row
        ("global token" sinks)."""
        m = cls.full(sq, skv, bq=bq, bk=bk, causal=causal, q_offset=q_offset)
        kinds = m.tile_kinds.copy()
        kinds[:, n_global:] = KIND_DEAD
        return cls(sq, skv, bq, bk, kinds, q_offset=q_offset)

    @classmethod
    def from_dense(cls, dense, *, bq: int = 128, bk: int = 128,
                   q_offset: int = 0) -> "BlockMask":
        """Arbitrary per-row block lists from a dense boolean (sq, skv) mask.

        Block-granular: a tile with any visible element becomes fully
        visible (sub-tile structure rounds UP to the tile) -- the oracle
        (``dense_mask``) reflects the rounded semantics.
        """
        dense = np.asarray(dense, bool)
        sq, skv = dense.shape
        n_q, n_kv = -(-sq // bq), -(-skv // bk)
        padded = np.zeros((n_q * bq, n_kv * bk), bool)
        padded[:sq, :skv] = dense
        any_vis = padded.reshape(n_q, bq, n_kv, bk).any(axis=(1, 3))
        kinds = np.where(any_vis, 0, KIND_DEAD).astype(np.int8)
        return cls(sq, skv, bq, bk, kinds, q_offset=q_offset)

    # -------------------------------------------------------------- pruning
    def _bbox_visible(self) -> np.ndarray:
        """(n_q, n_kv) bool: does each tile contain >= 1 visible pair under
        its own refinement bits?  Interval tests only (no S^2 materialize);
        for the causal+window combination bbox satisfiability of each edge
        implies a jointly-visible pair, so this is exact."""
        k = self.tile_kinds
        r = np.arange(self.n_q_tiles)[:, None]
        c = np.arange(self.n_kv_tiles)[None, :]
        q_lo = self.q_offset + r * self.bq
        q_hi = self.q_offset + np.minimum(r * self.bq + self.bq, self.sq) - 1
        k_lo = c * self.bk
        k_hi = np.minimum(c * self.bk + self.bk, self.skv) - 1
        vis = (k >= 0) & (r * self.bq < self.sq) & (c * self.bk < self.skv)
        vis &= np.where((k & KIND_CAUSAL) > 0, k_lo <= q_hi, True)
        if self.window is not None:
            vis &= np.where((k & KIND_WINDOW) > 0,
                            k_hi >= q_lo - self.window + 1, True)
        return vis

    def _pruned(self) -> "BlockMask":
        kinds = np.where(self._bbox_visible(), self.tile_kinds,
                         KIND_DEAD).astype(np.int8)
        return BlockMask(self.sq, self.skv, self.bq, self.bk, kinds,
                         window=self.window, q_offset=self.q_offset)

    # --------------------------------------------------------- composition
    def _compat_window(self, other: "BlockMask") -> int | None:
        if (self.sq, self.skv, self.bq, self.bk, self.q_offset) != \
                (other.sq, other.skv, other.bq, other.bk, other.q_offset):
            raise ValueError("BlockMask geometry mismatch")
        a_w = self.window if self._uses_window() else None
        b_w = other.window if other._uses_window() else None
        if a_w is not None and b_w is not None and a_w != b_w:
            raise ValueError(
                f"cannot compose masks with different windows ({a_w} vs {b_w})")
        return a_w if a_w is not None else b_w

    def _uses_window(self) -> bool:
        k = self.tile_kinds
        return bool(((k >= 0) & ((k & KIND_WINDOW) > 0)).any())

    def __and__(self, other: "BlockMask") -> "BlockMask":
        w = self._compat_window(other)
        a, b = self.tile_kinds, other.tile_kinds
        vis = (a >= 0) & (b >= 0)
        kinds = np.where(vis, a | b, KIND_DEAD).astype(np.int8)
        m = BlockMask(self.sq, self.skv, self.bq, self.bk, kinds, window=w,
                      q_offset=self.q_offset)
        return m._pruned()   # combined bits may empty a tile

    def __or__(self, other: "BlockMask") -> "BlockMask":
        w = self._compat_window(other)
        a, b = self.tile_kinds, other.tile_kinds
        va, vb = a >= 0, b >= 0
        kinds = np.full_like(a, KIND_DEAD)
        both = va & vb
        kinds[both] = (a & b)[both]          # union keeps the laxer refinement
        kinds[va & ~vb] = a[va & ~vb]
        kinds[vb & ~va] = b[vb & ~va]
        return BlockMask(self.sq, self.skv, self.bq, self.bk, kinds, window=w,
                         q_offset=self.q_offset)

    # --------------------------------------------------------------- oracle
    def dense_mask(self) -> np.ndarray:
        """(sq, skv) boolean oracle of exactly what the kernels compute."""
        q = self.q_offset + np.arange(self.sq)[:, None]
        k = np.arange(self.skv)[None, :]
        kinds = np.repeat(np.repeat(self.tile_kinds, self.bq, axis=0),
                          self.bk, axis=1)[:self.sq, :self.skv]
        vis = kinds >= 0
        vis &= np.where((kinds & KIND_CAUSAL) > 0, q >= k, True)
        if self.window is not None:
            vis &= np.where((kinds & KIND_WINDOW) > 0,
                            q - k < self.window, True)
        return vis

    def density(self) -> dict:
        vis = self.tile_kinds >= 0
        per_row = vis.sum(axis=1)
        dense = vis.size
        return {
            "n_q_tiles": self.n_q_tiles,
            "n_kv_tiles": self.n_kv_tiles,
            "nnzb": int(vis.sum()),
            "dense_tiles": int(dense),
            "block_fill": float(vis.sum() / dense),
            "row_blocks_min": int(per_row.min()),
            "row_blocks_max": int(per_row.max()),
            "row_blocks_mean": float(per_row.mean()),
        }

    # ------------------------------------------------------------- lowering
    def lower(self, *, bucket: bool = True, min_bucket: int = 8,
              capacity: int | None = None) -> MaskStream:
        """Lower to the sorted (row, col, kind) walk stream.

        Matches the BCSR stream contract: sorted by (row, col), every
        block-row present (empty rows get one KIND_DEAD entry at col 0), and
        bucket padding repeats the last (row, col) with KIND_DEAD so padded
        steps neither init, compute, nor finalize early.
        """
        vis = self.tile_kinds >= 0
        rows, cols = np.nonzero(vis)                 # row-major == (row, col)
        kinds = self.tile_kinds[rows, cols].astype(np.int64)
        present = np.zeros(self.n_q_tiles, bool)
        present[rows] = True
        missing = np.nonzero(~present)[0]
        if missing.size:
            rows = np.concatenate([rows, missing])
            cols = np.concatenate([cols, np.zeros_like(missing)])
            kinds = np.concatenate(
                [kinds, np.full(missing.size, KIND_DEAD, np.int64)])
            order = np.argsort(rows, kind="stable")
            rows, cols, kinds = rows[order], cols[order], kinds[order]
        n = int(rows.shape[0])
        if capacity is None:
            capacity = next_pow2(n, min_bucket) if bucket else n
        assert capacity >= n, (capacity, n)
        pad = capacity - n
        if pad:
            rows = np.concatenate([rows, np.full(pad, rows[-1])])
            cols = np.concatenate([cols, np.full(pad, cols[-1])])
            kinds = np.concatenate([kinds, np.full(pad, KIND_DEAD, np.int64)])
        return MaskStream(rows.astype(np.int32), cols.astype(np.int32),
                          kinds.astype(np.int32), self.n_q_tiles, n)

    # ------------------------------------------------------------- sharding
    def shard_rows(self, n_shards: int) -> list["BlockMask"]:
        """Split into per-shard sub-masks over contiguous q-tile ranges; each
        carries its absolute ``q_offset`` so refinements stay exact (the
        ``shard_spmm_batched_stream`` recipe for the query axis)."""
        nq = self.n_q_tiles
        assert nq % n_shards == 0, (nq, n_shards)
        assert self.sq == nq * self.bq, \
            "sharding requires sq aligned to bq tiles (pad first)"
        per = nq // n_shards
        sq_loc = per * self.bq
        return [
            BlockMask(sq_loc, self.skv, self.bq, self.bk,
                      self.tile_kinds[d * per:(d + 1) * per],
                      window=self.window,
                      q_offset=self.q_offset + d * sq_loc)
            for d in range(n_shards)
        ]

    # ----------------------------------------------------------- accounting
    def signature(self) -> tuple:
        """Stable pattern signature for compile accounting: two masks with
        equal signatures walk identical streams."""
        digest = zlib.crc32(np.ascontiguousarray(self.tile_kinds).tobytes())
        return ("blockmask", self.sq, self.skv, self.bq, self.bk,
                self.window, self.q_offset, int(digest))

    def __repr__(self) -> str:
        d = self.density()
        return (f"BlockMask({self.sq}x{self.skv}, tiles {self.bq}x{self.bk}, "
                f"nnzb={d['nnzb']}/{d['dense_tiles']}, window={self.window}, "
                f"q_offset={self.q_offset})")


@dataclasses.dataclass(frozen=True)
class AttnMaskSpec:
    """Hashable serving-level mask config -- the static-arg face of BlockMask.

    ``BlockMask`` holds numpy arrays, so it can't ride through the lru-cached
    per-layer jits; this frozen spec can, and expands to a concrete mask at
    trace time (``build``) from the static sequence length.

    * ``local=True``: route sliding-window prefill layers through the sparse
      walk (the layer's own window length applies).
    * ``pattern``: opt-in long-context mask for full-attention layers:
      ``"sliding"`` | ``"strided"`` | ``"local_global"`` (window+stride/
      n_global parameters below).
    * ``impl``: ``"sparse"`` (stream walk) | ``"dense"`` (masked full grid,
      the parity baseline) | ``"ref"`` (jnp oracle).
    """
    local: bool = True
    pattern: str | None = None
    window: int | None = None
    stride: int | None = None
    n_global: int = 1
    impl: str = "sparse"
    bq: int | None = None
    bk: int | None = None

    def build(self, sq: int, skv: int, *, layer_window: int | None,
              bq: int, bk: int) -> BlockMask | None:
        """Concrete mask for one layer, or None if the spec doesn't apply."""
        if layer_window is not None:
            if not self.local:
                return None
            return BlockMask.sliding_window(sq, skv, layer_window,
                                            bq=bq, bk=bk)
        if self.pattern is None:
            return None
        if self.pattern == "sliding":
            w = self.window or max(bk, skv // 4)
            return BlockMask.sliding_window(sq, skv, w, bq=bq, bk=bk)
        if self.pattern == "strided":
            local = BlockMask.sliding_window(sq, skv, self.window or bq,
                                             bq=bq, bk=bk)
            return BlockMask.strided(sq, skv, self.stride or 2,
                                     bq=bq, bk=bk) | local
        if self.pattern == "local_global":
            local = BlockMask.sliding_window(sq, skv,
                                             self.window or max(bk, skv // 4),
                                             bq=bq, bk=bk)
            return local | BlockMask.global_cols(sq, skv, self.n_global,
                                                 bq=bq, bk=bk)
        raise ValueError(f"unknown attn mask pattern: {self.pattern!r}")
