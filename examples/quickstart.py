"""Quickstart: train a tiny Occamy-style LM end to end on CPU (~1 min).

Shows the public API surface: config -> init -> data pipeline -> fault-
tolerant trainer -> checkpoint -> greedy decode with KV cache.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.data.pipeline import SyntheticLM
from repro.launch.train import make_step
from repro.models import model as M
from repro.optim.adamw import AdamW, cosine_schedule
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    cfg = dataclasses.replace(get_smoke("qwen3-1.7b"), policy="f32")
    steps = 60
    opt = AdamW(lr=cosine_schedule(3e-3, warmup=10, total=steps))
    data = SyntheticLM(cfg, batch=8, seq_len=64, seed=0, noise=0.05)
    trainer = Trainer(
        TrainerConfig(total_steps=steps, ckpt_every=25,
                      ckpt_dir="checkpoints/quickstart", log_every=10),
        cfg, make_step(cfg, opt), opt, data,
        init_state=lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    out = trainer.run()
    first, last = out["history"][0][1], out["history"][-1][1]
    print(f"\nloss: {first:.3f} -> {last:.3f} "
          f"({'LEARNED' if last < first - 0.5 else 'check hyperparams'})")

    # greedy decode from the trained model
    params = out["state"]["params"]
    prompt = jnp.asarray(data.batch_at(999)["tokens"][:1, :8])
    logits, cache, pos = M.prefill(params, prompt, cfg, max_seq=24)
    nxt = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)[:, None].astype(jnp.int32)
    toks = [int(nxt[0, 0])]
    for i in range(7):
        logits, cache = M.decode_step(params, cfg, cache, pos + i, nxt)
        nxt = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)[:, None].astype(jnp.int32)
        toks.append(int(nxt[0, 0]))
    want = [int(data.perm[t]) for t in [int(prompt[0, -1])] + toks[:-1]]
    hits = sum(a == b for a, b in zip(toks, want))
    print(f"decoded continuation: {toks}")
    print(f"next-token rule hits: {hits}/8 (data is a noisy permutation chain)")


if __name__ == "__main__":
    main()
