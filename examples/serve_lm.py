"""Serving example: batched prefill + KV-cache decode on any assigned arch.

Thin wrapper over the production launcher (repro.launch.serve) pinned to a
smoke config so it runs on CPU.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch gemma3-12b]
"""
import argparse
import sys

from repro.launch import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b")
    args, _ = ap.parse_known_args()
    sys.argv = ["serve", "--arch", args.arch, "--smoke", "--batch", "2",
                "--prompt-len", "24", "--gen", "16"]
    serve.main()


if __name__ == "__main__":
    main()
