"""Sparse showcase: the paper's three workloads on the core library +
Pallas kernels (interpret mode on CPU).

  1. stencil (Fig. 6a): j3d27pt through the halo-overlapped Pallas kernel
  2. SpMM (Fig. 6b): BCSR index stream driving the scalar-prefetch kernel
  3. SpMSpM (Fig. 6c): sorted-stream intersection + GCOMP accounting
  4. SU union: sparse gradient exchange primitive
  5. sharded + batched engine: the "48 clusters" layer -- the same kernels
     shard_map-partitioned over a virtual-device mesh, bit-for-bit equal

Run:  PYTHONPATH=src python examples/sparse_showcase.py
"""
from repro.kernels.engine import ensure_virtual_devices

ensure_virtual_devices(4)  # before the first jax backend touch

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (STENCILS, banded_sparse, bcsr_from_dense,
                        intersect, random_dense_sparse, topk_sparsify,
                        union_add)
from repro.core.formats import INVALID_KEY
from repro.kernels.spmm import ops as spmm_ops
from repro.kernels.spmm.ref import spmm_ref
from repro.kernels.spmspm import ops as spmspm_ops
from repro.kernels.spmspm.ref import spmspm_ref
from repro.kernels.stencil import ops as stencil_ops
from repro.kernels.stencil.ref import stencil_ref

rng = np.random.default_rng(0)

# 1 -- stencil
spec = STENCILS["j3d27pt"]
grid = jnp.asarray(rng.standard_normal((18, 24, 136)), jnp.float32)
out = stencil_ops.apply(grid, spec, tile=(4, 8, 128), interpret=True)
ref = stencil_ref(grid, spec)
print(f"[stencil j3d27pt] out {out.shape}, max|err| vs oracle: "
      f"{float(jnp.abs(out - ref).max()):.2e}, "
      f"flops={stencil_ops.flops(spec, out.shape):,}")

# 2 -- SpMM on the Pallas kernel (block index stream -> DMA steering)
a_dense = banded_sparse(rng, (128, 128), bandwidth=10)
a = bcsr_from_dense(a_dense, (8, 8))
b = jnp.asarray(rng.standard_normal((128, 256)), jnp.float32)
c = spmm_ops.spmm(a, b, interpret=True)
print(f"[spmm banded] nnzb={a.nnzb} block_density={a.density():.3f}, "
      f"max|err|: {float(jnp.abs(c - spmm_ref(a, b)).max()):.2e}")

# 3 -- SpMSpM: intersection kernel + index-comparison-rate accounting
left = random_dense_sparse(rng, (32, 256), 0.15)
right = random_dense_sparse(rng, (256, 32), 0.01)   # paper's 1% density
ak, av = spmspm_ops.dense_to_ell_rows(left)
bk, bv = spmspm_ops.dense_to_ell_cols(right)
cc = spmspm_ops.spmspm(ak, av, bk, bv, interpret=True)
st = spmspm_ops.comparison_stats(ak, bk)
print(f"[spmspm 1%] max|err|: "
      f"{float(jnp.abs(cc - spmspm_ref(ak, av, bk, bv, 256)).max()):.2e}, "
      f"comparisons issued={st['issued']:,} useful<={st['useful_upper']}")

# 4 -- SU stream ops: intersect / union (the comparator modes)
ka = jnp.asarray(np.sort(rng.choice(1000, 64, replace=False)).astype(np.int32))
kb = jnp.asarray(np.sort(rng.choice(1000, 96, replace=False)).astype(np.int32))
kb = jnp.pad(kb, (0, 32), constant_values=INVALID_KEY)
ka = jnp.pad(ka, (0, 64), constant_values=INVALID_KEY)
res = intersect(ka, kb)
print(f"[SU intersect] |A|=64 |B|=96 -> {int(res.count)} matches "
      f"(np.intersect1d agrees: "
      f"{np.array_equal(np.asarray(res.keys[:int(res.count)]), np.intersect1d(np.asarray(ka[:64]), np.asarray(kb[:96])))})")

g = jnp.asarray(rng.standard_normal(512), jnp.float32)
keys, vals = topk_sparsify(g, 32)
u = union_add(keys, vals, keys, vals)
print(f"[SU union] top-32 grad stream unioned with itself -> "
      f"{int(u.count)} keys, values doubled: "
      f"{bool(jnp.allclose(u.values[:32], 2 * vals[jnp.argsort(keys)]))}")

# 5 -- the sharded + batched engine (the multi-cluster layer)
from repro.core.formats import batched_bcsr_from_dense
from repro.kernels import engine

mesh = jax.make_mesh((jax.device_count(),), ("data",))
c_sh = engine.shard_spmm(a, b, mesh=mesh)
print(f"[engine shard_spmm x{jax.device_count()}] bit-for-bit vs 1-device: "
      f"{bool((np.asarray(c_sh) == np.asarray(c)).all())}")

stack = np.stack([random_dense_sparse(rng, (64, 64), 0.15) for _ in range(4)])
ab = batched_bcsr_from_dense(stack, (8, 8))
db = jnp.asarray(rng.standard_normal((4, 64, 96)), jnp.float32)
cb = engine.shard_spmm_batched(ab, db, mesh=mesh)
print(f"[engine batched x4 matrices] union-stream nnzb={ab.nnzb} "
      f"out={cb.shape}, max|err| vs per-matrix oracle: "
      f"{max(float(jnp.abs(cb[i] - spmm_ref(ab[i], db[i])).max()) for i in range(4)):.2e}")

cs = engine.shard_spmspm(ak, av, bk, bv, mesh=mesh)
print(f"[engine shard_spmspm] bit-for-bit vs 1-device: "
      f"{bool((np.asarray(cs) == np.asarray(cc)).all())}")
