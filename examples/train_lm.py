"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Default invocation uses a ~10M config so it completes on CPU in minutes;
pass ``--full`` for the ~100M x 300-step run (hours on CPU; the intended
host is a TPU slice where the same code path runs under pjit).

Run:  PYTHONPATH=src python examples/train_lm.py [--full]
"""
import argparse
import dataclasses

import jax

from repro.data.pipeline import SyntheticLM
from repro.launch.train import make_step
from repro.models import model as M
from repro.models.config import ArchConfig
from repro.optim.adamw import AdamW, cosine_schedule
from repro.runtime.trainer import Trainer, TrainerConfig


def make_cfg(full: bool) -> ArchConfig:
    if full:  # ~100M params (qwen3-family shape)
        return ArchConfig(
            name="lm-100m", family="dense", d_model=640, n_heads=10,
            n_kv_heads=5, d_ff=1792, vocab_size=32768,
            block_unit=("attn",), n_repeats=12, head_dim=64,
            qk_norm=True, policy="f32")
    return ArchConfig(
        name="lm-10m", family="dense", d_model=256, n_heads=4,
        n_kv_heads=2, d_ff=704, vocab_size=8192,
        block_unit=("attn",), n_repeats=6, head_dim=64,
        qk_norm=True, policy="f32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--grad-compress-k", type=int, default=0)
    args = ap.parse_args()
    cfg = make_cfg(args.full)
    steps = args.steps or (300 if args.full else 80)
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{steps} steps")
    opt = AdamW(lr=cosine_schedule(1e-3, warmup=steps // 10, total=steps))
    data = SyntheticLM(cfg, batch=8, seq_len=256 if args.full else 128,
                       seed=0, noise=0.05)
    trainer = Trainer(
        TrainerConfig(total_steps=steps, ckpt_every=max(25, steps // 4),
                      ckpt_dir=f"checkpoints/{cfg.name}", log_every=10),
        cfg, make_step(cfg, opt, args.grad_compress_k), opt, data,
        init_state=lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    out = trainer.run()
    hist = out["history"]
    print(f"\nloss {hist[0][1]:.3f} -> {hist[-1][1]:.3f}; "
          f"restart-safe checkpoints in checkpoints/{cfg.name}")


if __name__ == "__main__":
    main()
